"""Sharding-plan + HLO-introspection tests (mesh-free and tiny-mesh)."""

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config, get_smoke_config
from repro.models.params import DEFAULT_RULES, ParamDef, logical_to_pspec
from repro.perf.hlo import CollectiveOp, parse_collectives, _shape_bytes
from repro.perf.roofline import roofline_from_summary
from repro.perf.hlo import HloCostSummary


class TestLogicalToPspec:
    SIZES = {"data": 16, "model": 16, "pod": 2}

    def test_divisible_dims_shard(self):
        spec = logical_to_pspec(("embed", "heads", None), (4096, 64, 128), DEFAULT_RULES, self.SIZES)
        assert spec == P("data", "model")

    def test_non_divisible_falls_back(self):
        # phi3: 40 heads % 16 != 0 → replicated head dim, embed still sharded
        spec = logical_to_pspec(("embed", "heads", None), (5120, 40, 128), DEFAULT_RULES, self.SIZES)
        assert spec == P("data")

    def test_axis_used_once(self):
        # two logical dims both wanting "model": first wins
        rules = dict(DEFAULT_RULES, vocab="model", mlp="model")
        spec = logical_to_pspec(("vocab", "mlp"), (1600, 1600), rules, self.SIZES)
        assert spec == P("model")

    def test_multi_axis_batch(self):
        spec = logical_to_pspec(("batch", None), (256, 10), DEFAULT_RULES, self.SIZES)
        assert spec == P(("pod", "data"))

    def test_batch_partial_when_pod_missing(self):
        spec = logical_to_pspec(("batch", None), (256, 10), DEFAULT_RULES, {"data": 16, "model": 16})
        assert spec == P("data")


def _need_devices(n: int):
    import jax

    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} devices (covered by the subprocess dry-run test)")


class TestShardingPlan:
    def test_plan_covers_all_params(self):
        import jax

        _need_devices(4)
        from repro.launch.mesh import make_tiny_mesh
        from repro.launch.shardings import make_plan
        from repro.models import model_defs

        cfg = get_smoke_config("qwen2-72b")
        mesh = make_tiny_mesh()
        plan = make_plan(cfg, SHAPES["train_4k"], mesh)
        defs = model_defs(cfg)
        n_defs = len(jax.tree_util.tree_leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef)))
        n_specs = len(jax.tree_util.tree_leaves(plan.param_specs, is_leaf=lambda x: isinstance(x, P)))
        assert n_defs == n_specs

    def test_long_context_switches_to_sequence_parallel(self):
        import jax

        _need_devices(4)
        from repro.launch.mesh import make_tiny_mesh
        from repro.launch.shardings import make_plan
        from repro.models import init_cache

        cfg = get_smoke_config("jamba-1.5-large-398b")
        mesh = make_tiny_mesh()
        plan = make_plan(cfg, SHAPES["long_500k"], mesh)
        assert plan.long_context
        cache = jax.eval_shape(lambda: init_cache(cfg, 1, 64))
        specs = plan.cache_specs_fn(cache)
        flat = jax.tree_util.tree_flatten_with_path(specs)[0]
        kv = [s for p, s in flat if str(p[-1]).find("'k'") >= 0 or str(p[-1]).find("'v'") >= 0]
        assert any("data" in str(s) for s in kv)  # cache seq rides "data"

    def test_decode_batch_sharded_normally(self):
        import jax

        _need_devices(4)
        from repro.launch.mesh import make_tiny_mesh
        from repro.launch.shardings import make_plan

        cfg = get_smoke_config("deepseek-7b")
        plan = make_plan(cfg, SHAPES["decode_32k"], make_tiny_mesh())
        assert not plan.long_context


class TestHloParsing:
    SAMPLE = """
  %all-reduce.2 = f32[8,512]{1,0} all-reduce(%dot), channel_id=1, replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%add
  %ag = bf16[16,1024]{1,0} all-gather(%p), channel_id=2, replica_groups=[4,2]<=[8], dimensions={0}
  %rs = f32[4,128]{1,0} reduce-scatter(%x), channel_id=3, replica_groups=[2,4]<=[8], dimensions={0}, to_apply=%add
  %cp = u32[2]{0} collective-permute(%ids), channel_id=4, source_target_pairs={{0,1},{1,0}}
  %a2a = bf16[32,64]{1,0} all-to-all(%y), channel_id=5, replica_groups=[1,8]<=[8], dimensions={1}
  %not_a_collective = f32[2,2]{1,0} add(%a, %b)
"""

    def test_parse_kinds_and_sizes(self):
        ops = parse_collectives(self.SAMPLE)
        kinds = sorted(o.kind for o in ops)
        assert kinds == ["all-gather", "all-reduce", "all-to-all", "collective-permute", "reduce-scatter"]
        ar = next(o for o in ops if o.kind == "all-reduce")
        assert ar.result_bytes == 8 * 512 * 4
        assert ar.group_size == 4

    def test_wire_bytes_formulas(self):
        ar = CollectiveOp("all-reduce", 1024.0, 4)
        assert ar.wire_bytes == pytest.approx(2 * 1024 * 3 / 4)
        ag = CollectiveOp("all-gather", 1024.0, 4)
        assert ag.wire_bytes == pytest.approx(1024 * 3 / 4)
        cp = CollectiveOp("collective-permute", 1024.0, 1)
        assert cp.wire_bytes == 1024

    def test_shape_bytes_tuple_and_dtypes(self):
        assert _shape_bytes("bf16[4,8]") == 64
        assert _shape_bytes("(f32[2,2], s8[16])") == 32

    def test_real_lowered_module(self):
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding

        _need_devices(4)
        from repro.launch.mesh import make_tiny_mesh

        mesh = make_tiny_mesh()
        x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((64, 32), jnp.float32)

        def f(x, w):
            return (x @ w).sum()

        with mesh:
            compiled = (
                jax.jit(
                    f,
                    in_shardings=(
                        NamedSharding(mesh, P("data", "model")),
                        NamedSharding(mesh, P("model", None)),
                    ),
                )
                .lower(x, w)
                .compile()
            )
        ops = parse_collectives(compiled.as_text())
        assert any(o.kind.startswith("all-reduce") for o in ops)


class TestRooflineMath:
    def test_terms_and_dominant(self):
        s = HloCostSummary(
            flops_per_device=197e12,       # exactly one second of compute
            hbm_bytes_per_device=819e9 / 2, # half a second of HBM
            collective_wire_bytes_per_device=50e9 * 2,  # two seconds of ICI
        )
        t = roofline_from_summary(
            s, arch="a", shape="s", mesh="m", chips=256, model_flops_total=197e12 * 128
        )
        assert t.compute_s == pytest.approx(1.0)
        assert t.memory_s == pytest.approx(0.5)
        assert t.collective_s == pytest.approx(2.0)
        assert t.dominant == "collective"
        assert t.useful_flops_ratio == pytest.approx(0.5)
        # useful time = (197e12*128)/(256*197e12) = 0.5s; bound = 2s → 0.25
        assert t.roofline_fraction == pytest.approx(0.25)
