"""Multi-chip topology subsystem suite (docs/DESIGN.md §5.14).

* **structure/routing** — mesh/ring construction over the shared
  ``launch.mesh_shapes`` vocabulary, dimension-ordered deterministic
  routing, wrap semantics (no duplicate link at axis size 2).
* **conservation** — bytes injected at a route head land on every link of
  the route exactly once, on all three engines (`expected_link_bytes` /
  `DeviceTopology.check_conservation`), plus the registered ``dist_*``
  scenarios' per-stream oracles.
* **device axis** — ``filter(device=)`` / ``groupby("device")`` semantics,
  unattributed streams landing on device 0, unknown devices rejected.
* **invisibility when off** — a single-device topology is bit-identical to
  the legacy single-chip goldens (cycles, signature, report text).
* **hypothesis** — topology-shape draws: tri-engine signature identity and
  trace-cache invalidation (shape change ⇒ recompile; rerun ⇒ replay).
"""

import io
import re
import subprocess
import sys

import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core.query import QueryError
from repro.core.sinks import TextSink
from repro.sim import (
    DeviceTopology,
    SimConfig,
    all_reduce_ring,
    all_reduce_tree,
    all_to_all,
    expected_link_bytes,
    pipeline_send,
)
from repro.sim.compiled import TRACE_CACHE
from repro.sim.scenarios import build

ENGINES = ("cycle", "event", "compiled")
DIST_SCENARIOS = ("dist_dp_allreduce", "dist_pp_pipeline",
                  "dist_ep_alltoall", "dist_straggler")


@pytest.fixture(autouse=True)
def _fresh_trace_cache():
    TRACE_CACHE.clear()
    yield
    TRACE_CACHE.clear()


def _topo(shape, **kw):
    kw.setdefault("link_bytes_per_cycle", 64.0)
    return DeviceTopology(shape, **kw)


# ------------------------------------------------------------------ structure
class TestStructure:
    def test_axes_reuse_launch_vocabulary(self):
        assert _topo((4,)).axes == ("data",)
        assert _topo((2, 2)).axes == ("data", "model")
        assert _topo((2, 2, 2)).axes == ("pod", "data", "model")

    def test_coords_roundtrip(self):
        topo = _topo((2, 3))
        for d in range(topo.n_devices):
            assert topo.device_at(topo.coords(d)) == d
        assert topo.coords(5) == (1, 2)  # row-major

    def test_invalid_shapes_rejected(self):
        with pytest.raises(ValueError):
            _topo((2, 2, 2, 2))  # rank 4 outside the vocabulary
        with pytest.raises(ValueError):
            _topo((0,))

    def test_no_wrap_duplicate_at_size_two(self):
        # at axis size 2 the wrap link would duplicate the adjacent pair
        topo = _topo((2, 2))
        assert set(topo.links) == {(0, 1), (1, 0), (0, 2), (2, 0),
                                   (1, 3), (3, 1), (2, 3), (3, 2)}

    def test_ring_wrap_links(self):
        topo = _topo((4,))
        assert (3, 0) in topo.links and (0, 3) in topo.links
        assert (0, 3) not in _topo((4,), wrap=False).links


# -------------------------------------------------------------------- routing
class TestRouting:
    def test_ring_takes_shorter_direction(self):
        topo = _topo((4,))
        assert topo.route(0, 3) == (0, 3)       # wrap: 1 hop back beats 3 fwd
        assert topo.route(1, 3) == (1, 2, 3)    # tie (2 vs 2) breaks toward +1
        assert _topo((4,), wrap=False).route(0, 3) == (0, 1, 2, 3)

    def test_mesh_dimension_ordered(self):
        topo = _topo((2, 2))
        assert topo.route(0, 3) == (0, 2, 3)    # outermost axis first
        assert topo.route(3, 0) == (3, 1, 0)
        assert topo.route(1, 1) == (1,)

    def test_route_is_deterministic(self):
        topo = _topo((2, 3))
        for s in range(topo.n_devices):
            for d in range(topo.n_devices):
                assert topo.route(s, d) == topo.route(s, d)

    def test_expand_route_endpoints_only(self):
        topo = _topo((2, 2))
        assert topo.expand_route((0, 3)) == ((0, 2), (2, 3))
        assert topo.expand_route((0, 3, 0)) == ((0, 2), (2, 3), (3, 1), (1, 0))

    def test_hops_for_defaults_to_ring_successor(self):
        topo = _topo((4,))
        from repro.sim import KernelDesc

        kd = KernelDesc(name="k", ici_bytes=512, device=2)
        assert topo.hops_for(kd) == ((2, 3),)
        assert _topo((1,)).hops_for(KernelDesc(name="k", ici_bytes=512)) == ()


# --------------------------------------------------------------- conservation
class TestConservation:
    @pytest.mark.parametrize("engine", ENGINES)
    @pytest.mark.parametrize("collective", [
        lambda t: all_reduce_ring(t, 64 << 10),
        lambda t: all_reduce_tree(t, 32 << 10),
        lambda t: all_to_all(t, 8 << 10),
        lambda t: pipeline_send(t, 16 << 10, microbatches=2),
    ], ids=["ar_ring", "ar_tree", "a2a", "pp_send"])
    def test_link_bytes_conserved_per_engine(self, engine, collective):
        """Bytes injected at each route head land on every hop of the route
        exactly once — checked against the sim's actual link ledgers on all
        three engines (the compiled engine restores them from the trace)."""
        cfg = SimConfig(engine=engine, topology_shape=(2, 2))
        from repro.sim import TPUSimulator

        sim = TPUSimulator(cfg)
        descs = collective(sim.topology)
        for d in descs:
            sid = sim.create_stream(f"s{d.device}").stream_id
            sim.launch(sid, d)
        sim.run()
        check = sim.topology.check_conservation(descs)
        assert check["ok"], check["mismatches"]
        # every expected link is a real link of the mesh
        want = expected_link_bytes(sim.topology, descs)
        assert set(want) <= set(sim.topology.links)

    @pytest.mark.parametrize("name", DIST_SCENARIOS)
    @pytest.mark.parametrize("engine", ENGINES)
    def test_dist_scenario_oracles(self, name, engine):
        inst = build(name)
        res = inst.run(engine=engine)
        check = inst.check_oracle(res)
        assert check is not None and check["ok"], check

    @pytest.mark.parametrize("name", DIST_SCENARIOS)
    def test_dist_tri_engine_identity(self, name):
        inst = build(name)
        sigs = [inst.run(engine=e).signature() for e in ENGINES]
        assert sigs[0] == sigs[1] == sigs[2]


# ---------------------------------------------------------------- device axis
class TestDeviceAxis:
    def test_groupby_device_partitions_total(self):
        inst = build("dist_dp_allreduce", shape=(2, 2))
        res = inst.run(engine="event")
        frame = inst.frame(res)
        groups = frame.groupby("device").frames()
        assert sorted(groups) == [0, 1, 2, 3]
        assert sum(g.sum() for g in groups.values()) == frame.sum()

    def test_filter_device_matches_groupby(self):
        inst = build("dist_dp_allreduce", shape=(2, 2))
        frame = inst.frame(inst.run(engine="event"))
        for d, g in frame.groupby("device").frames().items():
            assert frame.filter(device=d).sum() == g.sum()

    def test_unattributed_streams_land_on_device_zero(self):
        # a legacy single-chip run has no device map: every stream —
        # including the default stream — groups under device 0
        inst = build("mixed_stream", n_streams=2)
        frame = inst.frame(inst.run(engine="event"))
        groups = frame.groupby("device").frames()
        assert list(groups) == [0]
        assert groups[0].sum() == frame.sum()
        assert frame.device_label(1) == 0

    def test_unknown_device_rejected(self):
        inst = build("dist_dp_allreduce", shape=(2, 2))
        frame = inst.frame(inst.run(engine="event"))
        with pytest.raises(QueryError, match="unknown device"):
            frame.filter(device=7)

    def test_result_devices_map(self):
        inst = build("dist_dp_allreduce", shape=(2, 2))
        res = inst.run(engine="event")
        # dp_{d} streams bind in first-appearance order: stream d+1 on device d
        assert res.devices == {1: 0, 2: 1, 3: 2, 4: 3}

    def test_launch_outside_topology_rejected(self):
        from repro.sim import KernelDesc, TPUSimulator

        sim = TPUSimulator(SimConfig(topology_shape=(2,)))
        sid = sim.create_stream("s").stream_id
        with pytest.raises(ValueError, match="device"):
            sim.launch(sid, KernelDesc(name="k", flops=1.0, device=5))
            sim.run()

    def test_ici_hops_excluded_from_demand(self):
        inst = build("dist_dp_allreduce", shape=(2, 2))
        frame = inst.frame(inst.run(engine="event"))
        counts = frame.filter(stream="dp_0").outcome_counts()
        assert counts["ICI_HOPS"] > 0
        # hop events ride their own traffic row — demand TOTAL excludes them
        demand = (counts["HIT"] + counts["MSHR_HIT"] + counts["MISS"]
                  + counts["VICTIM_HIT"] + counts["MISS_CACHE_HIT"]
                  + counts["PREFETCH_HIT"])
        assert counts["TOTAL"] == demand


# ----------------------------------------------------- single-device identity
#: pre-topology golden cycles (tests/test_scenarios.GOLDEN_CYCLES excerpt) —
#: a (1,)-topology run must reproduce these bit-for-bit on every engine.
SINGLE_DEVICE_GOLDENS = {"cache_thrash": 9602, "l2_lat": 608, "mixed_stream": 240}


class TestSingleDeviceIdentity:
    @pytest.mark.parametrize("scenario", sorted(SINGLE_DEVICE_GOLDENS))
    @pytest.mark.parametrize("engine", ENGINES)
    def test_bit_identical_to_goldens(self, scenario, engine):
        inst = build(scenario)
        bare = inst.run(engine=engine)
        topo = inst.run(engine=engine, config=SimConfig(topology_shape=(1,)))
        assert bare.cycles == SINGLE_DEVICE_GOLDENS[scenario]
        assert topo.cycles == bare.cycles
        assert topo.signature() == bare.signature()

    @pytest.mark.parametrize("scenario", sorted(SINGLE_DEVICE_GOLDENS))
    def test_report_text_identical(self, scenario):
        def text(config=None):
            buf = io.StringIO()
            inst = build(scenario)
            inst.make_sim(engine="event", config=config,
                          sinks=[TextSink(buf)]).run()
            # kernel uids come from a process-global counter: normalize so
            # only genuine report differences (counts, cycles, lanes) fail
            return re.sub(r"uid[ =]+\d+", "uid N", buf.getvalue())

        assert text(SimConfig(topology_shape=(1,))) == text()

    def test_single_device_topology_has_no_links(self):
        topo = _topo((1,))
        assert topo.n_devices == 1 and not topo.links


# ----------------------------------------------------------------- hypothesis
SHAPES = [(1,), (2,), (3,), (4,), (2, 2), (2, 3), (2, 2, 2)]

if HAVE_HYPOTHESIS:

    @settings(max_examples=12, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(shape=st.sampled_from(SHAPES),
           grad_kb=st.sampled_from([32, 64, 128]))
    def test_hypothesis_shapes_tri_engine_identity(shape, grad_kb):
        """Any vocabulary shape × payload: cycle == event == compiled, and
        the dist oracle holds."""
        TRACE_CACHE.clear()
        inst = build("dist_dp_allreduce", shape=shape, grad_kb=grad_kb)
        res = {e: inst.run(engine=e) for e in ENGINES}
        assert res["cycle"].signature() == res["event"].signature()
        assert res["event"].signature() == res["compiled"].signature()
        check = inst.check_oracle(res["event"])
        assert check is not None and check["ok"], check

    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(data=st.data())
    def test_hypothesis_shape_change_invalidates_trace(data):
        """Topology fields are structural: a shape change must recompile,
        a rerun at the same shape must replay from cache."""
        a = data.draw(st.sampled_from(SHAPES))
        b = data.draw(st.sampled_from([s for s in SHAPES if s != a]))
        TRACE_CACHE.clear()
        inst_a = build("dist_dp_allreduce", shape=a)
        inst_a.run(engine="compiled")
        assert (TRACE_CACHE.compiles, TRACE_CACHE.hits) == (1, 0)
        inst_a.run(engine="compiled")
        assert (TRACE_CACHE.compiles, TRACE_CACHE.hits) == (1, 1)
        build("dist_dp_allreduce", shape=b).run(engine="compiled")
        assert TRACE_CACHE.compiles == 2


class TestTraceCacheStructural:
    def test_wrap_and_link_rate_are_structural(self):
        inst = build("dist_dp_allreduce", shape=(4,))
        inst.run(engine="compiled")
        assert TRACE_CACHE.compiles == 1
        inst.run(engine="compiled", config=SimConfig(topology_wrap=False))
        assert TRACE_CACHE.compiles == 2
        inst.run(engine="compiled", config=SimConfig(link_bytes_per_cycle=8.0))
        assert TRACE_CACHE.compiles == 3

    def test_compiled_replay_restores_link_ledgers(self):
        inst = build("dist_dp_allreduce", shape=(2, 2))
        sim1 = inst.make_sim(engine="compiled")
        sim1.run()
        want = sim1.topology.link_bytes()
        assert any(want.values())
        sim2 = inst.make_sim(engine="compiled")  # cache hit → replay
        sim2.run()
        assert TRACE_CACHE.hits >= 1
        assert sim2.topology.link_bytes() == want


# ------------------------------------------------------------------- jax-free
def test_topology_import_is_jax_free():
    """The simulator's topology stack (including the shared
    ``launch.mesh_shapes`` vocabulary) must import without jax."""
    code = ("import repro, repro.sim.topology, repro.launch.mesh_shapes, sys; "
            "assert 'jax' not in sys.modules, 'topology import loaded jax'")
    subprocess.run([sys.executable, "-c", code], check=True)
