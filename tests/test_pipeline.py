"""Pipeline parallelism (GPipe over shard_map+ppermute) vs sequential ref."""

import os
import subprocess
import sys

ENV = {
    "PYTHONPATH": "src",
    "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
    "HOME": os.environ.get("HOME", "/root"),
    "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
    # Force the host backend: with a libtpu wheel present but no TPU attached,
    # backend autodetection hangs for minutes before falling back.
    "JAX_PLATFORMS": "cpu",
}

CODE = """
import jax, jax.numpy as jnp, numpy as np
from repro.train.pipeline import pipeline_forward, split_stages

# jax.make_mesh grew its axis_types kwarg after the pinned 0.4.x line; plain
# Auto axes are that version's default, so the two-arg call is equivalent.
mesh = jax.make_mesh((4, 2), ("stage", "data"))

L, D, M, MB = 8, 16, 6, 4
key = jax.random.PRNGKey(0)
W = jax.random.normal(key, (L, D, D)) * (D ** -0.5)
b = jax.random.normal(jax.random.fold_in(key, 1), (L, D)) * 0.1
params = {"w": W, "b": b}

def layer_fn(lp, x):
    return jnp.tanh(x @ lp["w"] + lp["b"])

xs = jax.random.normal(jax.random.fold_in(key, 2), (M, MB, D))

# sequential reference
def seq(x):
    for i in range(L):
        x = layer_fn({"w": W[i], "b": b[i]}, x)
    return x
ref = jax.vmap(seq)(xs)

stage_params = split_stages(params, 4)
with mesh:
    out = jax.jit(
        lambda p, x: pipeline_forward(p, x, layer_fn, mesh, "stage")
    )(stage_params, xs)

np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)

# gradients flow through the pipeline (ppermute is differentiable)
def loss(p, x):
    return jnp.sum(pipeline_forward(p, x, layer_fn, mesh, "stage") ** 2)
with mesh:
    g = jax.jit(jax.grad(loss))(stage_params, xs)
gn = sum(float(jnp.abs(l).sum()) for l in jax.tree_util.tree_leaves(g))
assert np.isfinite(gn) and gn > 0

# the lowered module really uses collective-permute
with mesh:
    txt = jax.jit(lambda p, x: pipeline_forward(p, x, layer_fn, mesh, "stage")).lower(
        stage_params, xs).compile().as_text()
assert "collective-permute" in txt
print("PIPELINE_OK")
"""


def test_gpipe_matches_sequential():
    proc = subprocess.run(
        [sys.executable, "-c", CODE],
        capture_output=True, text=True, timeout=600, env=ENV,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "PIPELINE_OK" in proc.stdout
