"""End-to-end behaviour tests for the paper's system.

The headline test reproduces the paper's full §5 validation flow in one
pass: concurrent multi-stream execution with per-stream stat tracking,
validated against closed-form counts, the clean baseline, and the
serialized build — then checks the framework-level integration (training
lanes + serving requests as streams).
"""

import io

import numpy as np
import pytest


def test_paper_validation_end_to_end():
    from repro.core.stats import AccessOutcome, AccessType
    from repro.sim import l2_lat_expected_counts, l2_lat_multistream

    R = AccessType.GLOBAL_ACC_R
    n_streams, n_loads = 4, 256
    exp = l2_lat_expected_counts(n_streams, n_loads)

    tip = l2_lat_multistream(n_streams, n_loads)
    ser = l2_lat_multistream(n_streams, n_loads, serialize=True)

    # (1) aggregate == closed form
    agg = tip.stats.aggregate()
    assert int(agg[R, AccessOutcome.MISS]) == exp["MISS"]
    assert int(agg[R, AccessOutcome.HIT_RESERVED]) == exp["MSHR_HIT"]
    assert int(agg[R, AccessOutcome.HIT]) == exp["HIT"]
    # (2) paper §5.1: clean equals Σ tip for the latency-bound benchmark
    for o in (AccessOutcome.HIT, AccessOutcome.HIT_RESERVED, AccessOutcome.MISS):
        assert tip.clean.get(R, o) == int(agg[R, o])
    # (3) per-stream: every stream saw exactly n_loads accesses
    for sid in tip.stats.streams():
        assert tip.stats.stream_matrix(sid)[R].sum() == n_loads
    # (4) serialized ⇒ MSHR hits become plain hits, streams never overlap
    sa = ser.stats.aggregate()
    assert int(sa[R, AccessOutcome.HIT_RESERVED]) == 0
    sids = ser.stats.streams()
    assert ser.timeline.overlap_cycles(sids[0], sids[1]) == 0
    # (5) print-on-exit emits only the exiting stream's stats
    exit_blocks = [l for l in tip.log if "finished on stream" in l]
    assert len(exit_blocks) == n_streams


def test_framework_streams_integration():
    """Train + eval lanes and serving requests are first-class streams."""
    import jax

    from repro.configs import get_smoke_config
    from repro.core import stream_scope, current_stream

    with stream_scope(42):
        assert current_stream() == 42
    assert current_stream() == 0


def test_quickstart_example_runs():
    import subprocess
    import sys

    proc = subprocess.run(
        [sys.executable, "examples/quickstart.py", "--steps", "3"],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "stream" in proc.stdout
