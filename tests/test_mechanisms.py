"""Tri-engine differential + golden suite for the VMEMCache miss-path
mechanism zoo (``SimConfig.miss_mechanism``).

Proves the ISSUE-6 acceptance criteria directly:

* **Tri-engine identity** — ``cycle.signature() == event.signature() ==
  compiled.signature()`` for every mechanism x registry scenario, and over
  hypothesis draws of mechanism geometry (victim/miss-cache entries, stream
  buffer count and depth).  The event engine's fast-forward windows and the
  compiled engine's trace snapshots must both carry mechanism state exactly.
* **"none" bit-identity** — the default config reproduces the pre-mechanism
  golden cycles/splits, reports zero on every new stat lane, and is unmoved
  by mechanism *geometry* fields while ``miss_mechanism="none"``.
* **Golden mechanism tables** — checked-in cycle counts and per-stream
  outcome splits for representative mechanism configs (empirically frozen;
  a timing or attribution change cannot slip through as a matched pair of
  engine regressions).
* **Compiled-cache invalidation** — mechanism/geometry changes are
  *structural* (new compile), ``VALUE_ONLY_CONFIG`` changes replay.

The engine set honors ``SCENARIO_ENGINES`` and the mechanism set honors
``MECHANISMS`` (comma-separated) so CI can run an engine x mechanism
conformance matrix; single-engine runs still pin goldens per engine.
"""

import os
import random

import pytest

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.sim.compiled import TRACE_CACHE
from repro.sim.executor import SimConfig
from repro.sim.resources import MISS_MECHANISMS, Bandwidth, VMEMCache
from repro.sim.scenarios import build, list_scenarios

ENGINES = tuple(
    e.strip()
    for e in os.environ.get("SCENARIO_ENGINES", "cycle,event,compiled").split(",")
    if e.strip()
)
MECHANISMS = tuple(
    m.strip()
    for m in os.environ.get("MECHANISMS", ",".join(MISS_MECHANISMS)).split(",")
    if m.strip()
)

MECH_LANES = ("VICTIM_HIT", "MISS_CACHE_HIT", "PREFETCH_HIT", "PREFETCH_ISSUED")

#: Pre-mechanism golden cycles (mirrors tests/test_scenarios.py) — the
#: ``miss_mechanism="none"`` bit-identity reference.
GOLDEN_CYCLES_NONE = {
    "cache_thrash": 9602,
    "copy_compute_overlap": 798,
    "deepbench": 5133,
    "dist_dp_allreduce": 131,
    "dist_ep_alltoall": 67,
    "dist_pp_pipeline": 322,
    "dist_straggler": 512,
    "fault_kernel_abort": 18,
    "fault_straggler": 262,
    "fork_join": 163,
    "l2_lat": 608,
    "mixed_stream": 240,
    "mps_like": 576,
    "poisson_burst": 132,
    "priority_preemption": 128,
    "producer_consumer": 725,
    "straggler": 512,
}

#: Golden total cycles for mechanism configs at scenario defaults.
#: cache_thrash is the mechanism-sensitive workload (two chase streams
#: LRU-thrashing a 32-line cache); mixed_stream's near-lockstep sharing is
#: MSHR-dominated, so every mechanism leaves its cycle count untouched.
GOLDEN_MECH_CYCLES = {
    # (scenario, mechanism, geometry overrides) -> total cycles
    ("cache_thrash", "none", ()): 9602,
    ("cache_thrash", "victim", ()): 9602,           # 8 entries << 32-line reuse
    ("cache_thrash", "miss_cache", ()): 9602,       # 8 entries << 64-line miss stream
    ("cache_thrash", "stream_buffer", ()): 2126,    # sequential chase: prefetch covers
    ("cache_thrash", "victim+stream", ()): 2126,
    ("cache_thrash", "victim", (("victim_entries", 32),)): 3714,
    ("cache_thrash", "victim", (("victim_entries", 64),)): 3714,
    ("cache_thrash", "miss_cache", (("miss_cache_entries", 64),)): 3714,
    ("cache_thrash", "stream_buffer", (("stream_buffers", 1),)): 9602,  # ping-pong
    ("cache_thrash", "stream_buffer", (("stream_buffers", 2), ("stream_buffer_depth", 1))): 4826,
    ("mixed_stream", "none", ()): 240,
    ("mixed_stream", "victim", ()): 240,
    ("mixed_stream", "miss_cache", ()): 240,
    ("mixed_stream", "stream_buffer", ()): 240,
    ("mixed_stream", "victim+stream", ()): 240,
}

#: Golden per-stream outcome splits for mechanism configs (only rows whose
#: keys are asserted; unlisted lanes are implicitly pinned to the values in
#: the dict — every listed dict is compared key-by-key).
GOLDEN_MECH_SPLITS = {
    ("cache_thrash", "stream_buffer", ()): {
        "thrash_a": {"MISS": 3, "PREFETCH_HIT": 93, "PREFETCH_ISSUED": 105,
                     "VICTIM_HIT": 0, "MISS_CACHE_HIT": 0, "TOTAL": 96},
        "thrash_b": {"MISS": 3, "PREFETCH_HIT": 93, "PREFETCH_ISSUED": 105,
                     "VICTIM_HIT": 0, "MISS_CACHE_HIT": 0, "TOTAL": 96},
    },
    ("cache_thrash", "victim", (("victim_entries", 32),)): {
        "thrash_a": {"MISS": 32, "VICTIM_HIT": 64, "PREFETCH_HIT": 0,
                     "PREFETCH_ISSUED": 0, "TOTAL": 96},
        "thrash_b": {"MISS": 32, "VICTIM_HIT": 64, "PREFETCH_HIT": 0,
                     "PREFETCH_ISSUED": 0, "TOTAL": 96},
    },
    ("mixed_stream", "stream_buffer", ()): {
        "": {"HIT": 701, "MSHR_HIT": 3, "MISS": 2, "PREFETCH_HIT": 254,
             "PREFETCH_ISSUED": 262, "TOTAL": 960},
        "stream_1": {"HIT": 254, "MSHR_HIT": 2, "MISS": 1, "PREFETCH_HIT": 127,
                     "PREFETCH_ISSUED": 131, "TOTAL": 384},
        "stream_2": {"HIT": 254, "MSHR_HIT": 2, "MISS": 1, "PREFETCH_HIT": 127,
                     "PREFETCH_ISSUED": 131, "TOTAL": 384},
        "stream_3": {"HIT": 254, "MSHR_HIT": 2, "MISS": 1, "PREFETCH_HIT": 127,
                     "PREFETCH_ISSUED": 131, "TOTAL": 384},
    },
}


def cfg_for(mechanism, overrides=()):
    return SimConfig(miss_mechanism=mechanism, **dict(overrides))


def run_engines(name, cfg, params=None):
    """Run a scenario under ``cfg`` on every engine in ENGINES; assert the
    signatures are identical and return the first result."""
    inst = build(name, **(params or {}))
    results = {e: inst.run(engine=e, config=cfg) for e in ENGINES}
    sigs = {e: r.signature() for e, r in results.items()}
    first = ENGINES[0]
    for e in ENGINES[1:]:
        assert sigs[e] == sigs[first], (
            f"{name} x {cfg.miss_mechanism}: engine {e!r} diverges from {first!r}"
        )
    return inst, results[first]


# --------------------------------------------------------------------- identity
class TestTriEngineIdentity:
    @pytest.mark.parametrize("mechanism", MECHANISMS)
    @pytest.mark.parametrize("name", sorted(list_scenarios()))
    def test_registry_identity(self, name, mechanism):
        inst, res = run_engines(name, cfg_for(mechanism))
        # demand-access conservation: mechanisms reclassify misses, they
        # never create or destroy demand accesses
        base = inst.run(engine=ENGINES[0], config=SimConfig())
        for sid in res.stats.streams():
            got = inst.frame(res).filter(stream=sid).outcome_counts()
            want = inst.frame(base).filter(stream=sid).outcome_counts()
            assert got["TOTAL"] == want["TOTAL"], (name, mechanism, sid)
        # the oracle (when a mechanism adjuster makes an analytic claim)
        check = inst.check_oracle(res, config=cfg_for(mechanism))
        if check is not None:
            assert check["ok"], (name, mechanism, check["mismatches"])


# ----------------------------------------------------------------- none-identity
class TestNoneBitIdentity:
    @pytest.mark.parametrize("name", sorted(list_scenarios()))
    def test_golden_cycles_and_zero_lanes(self, name):
        inst = build(name)
        res = inst.run(engine=ENGINES[0], config=SimConfig())
        assert res.cycles == GOLDEN_CYCLES_NONE[name]
        counts = inst.frame(res).outcome_counts()
        for lane in MECH_LANES:
            assert counts[lane] == 0, (name, lane, counts[lane])

    def test_geometry_inert_while_none(self):
        """Mechanism geometry fields are structural (compiled recompiles)
        but must not perturb results while miss_mechanism='none'."""
        base = build("cache_thrash").run(engine=ENGINES[0], config=SimConfig())
        tweaked = build("cache_thrash").run(
            engine=ENGINES[0],
            config=SimConfig(victim_entries=3, miss_cache_entries=5,
                             stream_buffers=2, stream_buffer_depth=7),
        )
        assert tweaked.signature() == base.signature()


# ---------------------------------------------------------------------- goldens
class TestMechanismGoldens:
    @pytest.mark.parametrize("key", sorted(GOLDEN_MECH_CYCLES, key=repr))
    def test_golden_cycles(self, key):
        name, mechanism, overrides = key
        if mechanism not in MECHANISMS:
            pytest.skip(f"{mechanism} not in MECHANISMS axis")
        _, res = run_engines(name, cfg_for(mechanism, overrides))
        assert res.cycles == GOLDEN_MECH_CYCLES[key], key

    @pytest.mark.parametrize("key", sorted(GOLDEN_MECH_SPLITS, key=repr))
    def test_golden_splits(self, key):
        name, mechanism, overrides = key
        if mechanism not in MECHANISMS:
            pytest.skip(f"{mechanism} not in MECHANISMS axis")
        inst, res = run_engines(name, cfg_for(mechanism, overrides))
        frame = inst.frame(res)
        for sname, exp in GOLDEN_MECH_SPLITS[key].items():
            got = frame.filter(stream=sname).outcome_counts()
            for k, want in exp.items():
                assert got[k] == want, (key, sname, k, got)


# --------------------------------------------------------------- geometry draws
def geometry_draw(rng: random.Random) -> dict:
    return {
        "miss_mechanism": rng.choice([m for m in MISS_MECHANISMS if m != "none"]),
        "victim_entries": rng.randint(1, 48),
        "miss_cache_entries": rng.randint(1, 48),
        "stream_buffers": rng.randint(1, 6),
        "stream_buffer_depth": rng.randint(1, 6),
    }


class TestGeometrySeeded:
    """Seeded geometry sweep — always runs, so the CI matrix exercises
    mechanism geometry even without hypothesis installed."""

    @pytest.mark.parametrize("seed", range(8))
    def test_cache_thrash_geometry(self, seed):
        geom = geometry_draw(random.Random(seed))
        # small thrash shape keeps each tri-engine run cheap
        run_engines("cache_thrash", SimConfig(**geom),
                    params={"arr_lines": 16, "passes": 2})

    @pytest.mark.parametrize("seed", range(100, 104))
    def test_producer_consumer_geometry(self, seed):
        geom = geometry_draw(random.Random(seed))
        run_engines("producer_consumer", SimConfig(**geom))


if HAVE_HYPOTHESIS:

    GEOMETRY = st.fixed_dictionaries(
        {
            "miss_mechanism": st.sampled_from(
                [m for m in MISS_MECHANISMS if m != "none"]
            ),
            "victim_entries": st.integers(min_value=1, max_value=48),
            "miss_cache_entries": st.integers(min_value=1, max_value=48),
            "stream_buffers": st.integers(min_value=1, max_value=6),
            "stream_buffer_depth": st.integers(min_value=1, max_value=6),
        }
    )

    @settings(max_examples=12, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(geom=GEOMETRY)
    def test_geometry_hypothesis(geom):
        run_engines("cache_thrash", SimConfig(**geom),
                    params={"arr_lines": 16, "passes": 2})


# ------------------------------------------------------------ compiled trace key
@pytest.mark.skipif("compiled" not in ENGINES, reason="compiled engine excluded")
class TestCompiledInvalidation:
    def _run(self, cfg):
        return build("l2_lat").run(engine="compiled", config=cfg)

    def test_mechanism_change_recompiles_value_change_replays(self):
        TRACE_CACHE.clear()
        self._run(SimConfig(miss_mechanism="victim"))
        assert TRACE_CACHE.compiles == 1

        # value-only change: same structural key, trace replays
        self._run(SimConfig(miss_mechanism="victim", max_cycles=1 << 21))
        assert TRACE_CACHE.compiles == 1
        assert TRACE_CACHE.hits >= 1

        # mechanism change: structural key moves, fresh compile
        self._run(SimConfig(miss_mechanism="miss_cache"))
        assert TRACE_CACHE.compiles == 2

        # geometry change within one mechanism is structural too
        self._run(SimConfig(miss_mechanism="victim", victim_entries=16))
        assert TRACE_CACHE.compiles == 3

        # back to the first config: replay, not recompile
        self._run(SimConfig(miss_mechanism="victim"))
        assert TRACE_CACHE.compiles == 3

    def test_structural_key_carries_mechanism_fields(self):
        a = SimConfig(miss_mechanism="victim").structural_key()
        b = SimConfig(miss_mechanism="miss_cache").structural_key()
        c = SimConfig(miss_mechanism="victim", victim_entries=9).structural_key()
        d = SimConfig(miss_mechanism="victim", max_cycles=123456).structural_key()
        assert a != b and a != c
        assert a == d  # max_cycles is VALUE_ONLY_CONFIG


# ----------------------------------------------------------------------- guards
class TestMechanismGuards:
    def test_unknown_mechanism_rejected(self):
        with pytest.raises(ValueError, match="miss_mechanism"):
            VMEMCache(4096, 128, Bandwidth(64.0), miss_mechanism="victim_cache")

    def test_registry_constant_matches_config_domain(self):
        assert MISS_MECHANISMS == (
            "none", "victim", "miss_cache", "stream_buffer", "victim+stream"
        )
