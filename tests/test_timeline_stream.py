"""KernelTimeline (gpu_kernel_time analog) + StreamManager semantics."""

import io

import pytest

from repro.core.stream import StreamManager
from repro.core.timeline import KernelTimeline
from repro.core.collector import StatCollector, namespace_stream, split_namespaced
from repro.core.stats import AccessType, AccessOutcome, StatTable


class TestKernelTimeline:
    def test_launch_done_and_last_fields(self):
        tl = KernelTimeline()
        tl.on_launch(2, 10, cycle=100, name="k")
        assert (tl.last_streamID, tl.last_uid) == (2, 10)
        tl.on_done(2, 10, cycle=250)
        kt = tl.get(2, 10)
        assert kt.start_cycle == 100 and kt.end_cycle == 250 and kt.duration == 150

    def test_double_launch_and_done_rejected(self):
        tl = KernelTimeline()
        tl.on_launch(1, 1, 0)
        with pytest.raises(ValueError):
            tl.on_launch(1, 1, 5)
        tl.on_done(1, 1, 9)
        with pytest.raises(ValueError):
            tl.on_done(1, 1, 12)
        with pytest.raises(KeyError):
            tl.on_done(1, 99, 1)

    def test_overlap_and_spans(self):
        tl = KernelTimeline()
        tl.on_launch(1, 1, 0); tl.on_done(1, 1, 100)
        tl.on_launch(2, 2, 50); tl.on_done(2, 2, 150)
        assert tl.overlap_cycles(1, 2) == 50
        assert tl.makespan() == 150
        assert tl.serialized_span() == 200

    def test_print_kernel_format(self):
        tl = KernelTimeline()
        tl.on_launch(3, 7, 11, "foo"); tl.on_done(3, 7, 42)
        buf = io.StringIO()
        tl.print_kernel(buf, 3, 7)
        assert "kernel_launch_uid = 7 stream = 3 start_cycle = 11 end_cycle = 42" in buf.getvalue()


class TestStreamManager:
    def test_fifo_within_stream(self):
        sm = StreamManager()
        s = sm.create_stream("s")
        a = sm.launch(s.stream_id, "a")
        b = sm.launch(s.stream_id, "b")
        c0 = sm.launchable()
        assert [w.uid for w in c0] == [a.uid]
        sm.mark_launched(a)
        assert sm.launchable() == []  # stream busy
        sm.mark_done(a)
        assert [w.uid for w in sm.launchable()] == [b.uid]

    def test_streams_concurrent_but_serialize_patch(self):
        sm = StreamManager()
        s1, s2 = sm.create_stream(), sm.create_stream()
        a = sm.launch(s1.stream_id, "a")
        b = sm.launch(s2.stream_id, "b")
        assert {w.uid for w in sm.launchable()} == {a.uid, b.uid}
        sm.mark_launched(a)
        # concurrent: b still launchable; serialized (busy_streams nonempty): not
        assert [w.uid for w in sm.launchable()] == [b.uid]
        assert sm.launchable(serialize=True) == []
        sm.mark_done(a)
        assert [w.uid for w in sm.launchable(serialize=True)] == [b.uid]

    def test_cross_stream_events(self):
        sm = StreamManager()
        s1, s2 = sm.create_stream(), sm.create_stream()
        ev = sm.create_event()
        a = sm.launch(s1.stream_id, "a", record_events=[ev.event_id])
        b = sm.launch(s2.stream_id, "b", wait_events=[ev.event_id])
        assert [w.uid for w in sm.launchable()] == [a.uid]  # b blocked on event
        sm.mark_launched(a)
        sm.mark_done(a)
        assert ev.fired
        assert [w.uid for w in sm.launchable()] == [b.uid]


class TestCollector:
    def test_namespacing_roundtrip(self):
        g = namespace_stream(3, 17)
        assert split_namespaced(g) == (3, 17)

    def test_combine_across_hosts(self):
        snaps = []
        for host in range(3):
            t = StatTable()
            t.inc_stats(AccessType.GLOBAL_ACC_R, AccessOutcome.HIT, 1, n=host + 1)
            snaps.append(StatCollector(host, 3, namespace_streams=True).snapshot(t))
        merged = StatCollector.combine(snaps)
        assert len(merged.streams()) == 3  # one namespaced stream per host
        assert int(merged.aggregate()[AccessType.GLOBAL_ACC_R, AccessOutcome.HIT]) == 6

    def test_shared_stream_merge(self):
        snaps = []
        for host in range(2):
            t = StatTable()
            t.inc_stats(AccessType.ICI_SND, AccessOutcome.MISS, 5, n=10)
            snaps.append(StatCollector(host, 2, namespace_streams=False).snapshot(t))
        merged = StatCollector.combine(snaps)
        assert merged.streams() == (5,)
        assert merged.get(AccessType.ICI_SND, AccessOutcome.MISS, 5) == 20


class TestStreamStatsRetire:
    """Bounded-memory fold (docs/DESIGN.md §5.12): retiring a stream folds
    its StepRecords into a constant-size aggregate without changing any
    summary — float-for-float."""

    def _stats_with(self, streams=(1, 2), steps=3):
        from repro.core.instrument import StepCost, StreamStats

        st = StreamStats()
        for sid in streams:
            for k in range(steps):
                uid = st.step_begin(f"s{k}", sid)
                st.step_end(
                    uid,
                    tokens=2 + k,
                    cost=StepCost(flops=1e6 + k, hbm_bytes=512.5, collective_bytes=64.0),
                )
        return st

    def test_fold_preserves_summary_exactly(self):
        st = self._stats_with()
        before = {sid: st.summary(sid) for sid in st.streams()}
        assert st.retire_stream(1) == 3
        assert st.summary(1) == before[1]  # retired: agg only
        assert st.summary(2) == before[2]  # live: records only
        assert st.streams() == (1, 2)

    def test_fold_drops_records_and_timeline(self):
        st = self._stats_with()
        assert any(r.stream_id == 1 for r in st.records)
        assert 1 in st.timeline.gpu_kernel_time
        st.retire_stream(1)
        assert not any(r.stream_id == 1 for r in st.records)
        assert 1 not in st.timeline.gpu_kernel_time
        assert 2 in st.timeline.gpu_kernel_time  # other streams untouched
        assert st.retire_stream(1) == 0  # idempotent

    def test_late_records_fold_into_existing_aggregate(self):
        st = self._stats_with(streams=(7,), steps=2)
        st.retire_stream(7)
        uid = st.step_begin("late", 7)
        st.step_end(uid, tokens=5)
        combined = st.summary(7)
        assert combined["steps"] == 3 and combined["tokens"] == 2 + 3 + 5
        st.retire_stream(7)  # second fold absorbs the late record
        assert st.summary(7) == combined

    def test_unknown_stream_reports_zero(self):
        from repro.core.instrument import StreamStats

        st = StreamStats()
        assert st.summary(99) == {"steps": 0}
        assert st.retire_stream(99) == 0
        assert st.summary(99) == {"steps": 0}

    def test_reports_identical_across_fold(self):
        import io

        st = self._stats_with()
        before = io.StringIO()
        st.print_summary(before)
        st.retire_stream(1)
        st.retire_stream(2)
        after = io.StringIO()
        st.print_summary(after)
        assert before.getvalue() == after.getvalue()
