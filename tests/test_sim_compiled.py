"""Compiled-trace engine: tri-engine identity, cache invalidation, lockstep.

The contract under test (ISSUE 4):

* ``cycle.sig == event.sig == compiled.sig`` for every registered scenario —
  at defaults, over randomized draws from each scenario's declared space,
  and under hypothesis;
* the trace cache recompiles on *shape* changes and replays on *value-only*
  changes (``max_cycles``/``verbose``), with the replay still bit-identical;
* a snapshot-restored stat engine equals landing the recorded journal
  segment-by-segment through ``record_batch`` (the identity argument for
  the fast replay path);
* ``replay_batch`` materializes independent per-run results whose lockstep
  resource columns match the compile run's final counters;
* ``BatchRunner(backend="vector")`` is bit-identical to the serial pool
  path, simulating each shape exactly once.
"""

import random

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.sim import KernelDesc, SimConfig, TPUSimulator, l2_lat_multistream, pointer_chase_trace
from repro.sim.batch import BatchJob, BatchRunner, same_shape_jobs, sweep_jobs
from repro.sim.compiled import TRACE_CACHE, get_or_compile, replay_batch, replay_journal
from repro.sim.executor import VALUE_ONLY_CONFIG
from repro.sim.scenarios import build, list_scenarios, space_draws, value_only_draws


@pytest.fixture(autouse=True)
def _fresh_cache():
    TRACE_CACHE.clear()
    yield
    TRACE_CACHE.clear()


def _tri_identical(inst, config=None):
    sigs = {
        eng: inst.run(engine=eng, config=config).signature()
        for eng in ("cycle", "event", "compiled")
    }
    for key in sigs["cycle"]:
        assert sigs["cycle"][key] == sigs["event"][key], f"cycle!=event in {key!r}"
        assert sigs["event"][key] == sigs["compiled"][key], f"event!=compiled in {key!r}"
    return sigs["event"]


class TestTriEngineIdentity:
    @pytest.mark.parametrize("name", list_scenarios())
    def test_registry_defaults(self, name):
        _tri_identical(build(name))

    @pytest.mark.parametrize("name", list_scenarios())
    def test_registry_defaults_replay_hit(self, name):
        """Second compiled run of one shape is a cache *hit* and still
        bit-identical to the event engine."""
        inst = build(name)
        a = inst.run(engine="compiled")
        assert TRACE_CACHE.compiles == 1
        b = inst.run(engine="compiled")
        assert TRACE_CACHE.hits >= 1 and TRACE_CACHE.compiles == 1
        assert a.signature() == b.signature() == inst.run(engine="event").signature()

    @pytest.mark.parametrize("seed", range(4))
    def test_randomized_registry_draws(self, seed):
        rng = random.Random(seed)
        for name in rng.sample(list_scenarios(), 3):
            params = space_draws(name, 1, seed=seed)[0]
            _tri_identical(build(name, **params))

    def test_direct_simulator_api(self):
        """engine="compiled" through the raw TPUSimulator API (no scenario):
        two structurally-equal workloads share one trace; results match the
        event engine."""

        def make(engine):
            sim = TPUSimulator(SimConfig(engine=engine))
            s = sim.create_stream()
            sim.launch(s.stream_id, KernelDesc(
                name="chase", trace=pointer_chase_trace(1 << 20, 96), dependent=True))
            return sim

        ref = make("event").run().signature()
        assert make("compiled").run().signature() == ref  # compile
        assert make("compiled").run().signature() == ref  # replay
        assert TRACE_CACHE.compiles == 1 and TRACE_CACHE.hits == 1

    def test_microbench_wrapper(self):
        a = l2_lat_multistream(4, 128, engine="event").signature()
        b = l2_lat_multistream(4, 128, engine="compiled").signature()
        c = l2_lat_multistream(4, 128, engine="compiled").signature()
        assert a == b == c


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(data=st.data())
    def test_tri_engine_differential_hypothesis(data):
        """Hypothesis-driven draw over the registry: scenario + params from
        its declared space must satisfy cycle == event == compiled."""
        name = data.draw(st.sampled_from(list_scenarios()))
        spec_draws = space_draws(name, 4, seed=data.draw(st.integers(0, 999)))
        params = data.draw(st.sampled_from(spec_draws))
        TRACE_CACHE.clear()
        _tri_identical(build(name, **params))


class TestTraceCacheInvalidation:
    def test_value_only_change_replays(self):
        """A value-only SimConfig change (max_cycles) must NOT recompile —
        and the replay stays bit-identical to a fresh event run."""
        inst = build("l2_lat", n_loads=128)
        inst.run(engine="compiled", config=SimConfig(max_cycles=10_000_000))
        assert (TRACE_CACHE.compiles, TRACE_CACHE.hits) == (1, 0)
        res = inst.run(engine="compiled", config=SimConfig(max_cycles=20_000_000))
        assert (TRACE_CACHE.compiles, TRACE_CACHE.hits) == (1, 1)
        assert res.signature() == inst.run(
            engine="event", config=SimConfig(max_cycles=20_000_000)).signature()

    def test_verbose_is_value_only(self, capsys):
        inst = build("mps_like", tenants=2, kernels_each=1)
        quiet = inst.run(engine="compiled")
        cfg = SimConfig(verbose=True)
        loud = inst.run(engine="compiled", config=cfg)
        assert TRACE_CACHE.compiles == 1 and TRACE_CACHE.hits == 1
        assert loud.signature() == quiet.signature()
        assert "launching kernel" in capsys.readouterr().out  # replay still prints

    def test_shape_param_change_recompiles(self):
        inst_a = build("l2_lat", n_loads=128)
        inst_b = build("l2_lat", n_loads=256)  # scenario param ⇒ new shape
        inst_a.run(engine="compiled")
        inst_b.run(engine="compiled")
        assert TRACE_CACHE.compiles == 2 and TRACE_CACHE.hits == 0

    def test_structural_config_change_recompiles(self):
        inst = build("l2_lat", n_loads=128)
        inst.run(engine="compiled", config=SimConfig(hbm_latency=100))
        inst.run(engine="compiled", config=SimConfig(hbm_latency=60))
        assert TRACE_CACHE.compiles == 2 and TRACE_CACHE.hits == 0
        # ... and each shape's replay matches its own event run
        for lat in (100, 60):
            a = inst.run(engine="compiled", config=SimConfig(hbm_latency=lat))
            b = inst.run(engine="event", config=SimConfig(hbm_latency=lat))
            assert a.signature() == b.signature()

    def test_max_cycles_guard_parity(self):
        """A draw whose max_cycles is too small raises from replay exactly
        like the event engine raises mid-run."""
        inst = build("l2_lat", n_loads=256)
        inst.run(engine="compiled")  # compile with ample budget
        tiny = SimConfig(max_cycles=50)
        with pytest.raises(RuntimeError, match="max_cycles=50"):
            inst.run(engine="event", config=tiny)
        with pytest.raises(RuntimeError, match="max_cycles=50"):
            inst.run(engine="compiled", config=tiny)
        assert TRACE_CACHE.compiles == 1  # the guard fired on a cache hit

    def test_lru_eviction_bounds_memory(self):
        from repro.sim.compiled import TraceCache

        small = TraceCache(max_entries=2)
        for n in (32, 64, 96):
            sim = TPUSimulator(SimConfig())
            s = sim.create_stream()
            sim.launch(s.stream_id, KernelDesc(
                name="k", trace=pointer_chase_trace(0, n), dependent=True))
            from repro.sim.compiled import _compile, shape_key

            key = shape_key(sim)
            trace, _ = _compile(sim)
            trace.key = key
            small.put(key, trace)
        assert len(small) == 2


class TestReplayInternals:
    def test_snapshot_restore_equals_journal_landing(self):
        """The fast replay path (snapshot block copy) must equal the
        semantic definition (per-segment record_batch landing of the
        recorded journal) bit-for-bit, across stat views and clean lanes."""
        for name, params in (
            ("l2_lat", dict(n_loads=256)),
            ("cache_thrash", dict(arr_lines=32, passes=4)),
            ("mixed_stream", dict(n=1 << 12)),
        ):
            inst = build(name, **params)
            sim = inst.make_sim(engine="event")
            trace, compiled_res = get_or_compile(sim)
            journal_engine = replay_journal(trace)
            assert journal_engine.signature() == compiled_res.stats.signature(), name
            replayed = replay_batch(trace, [SimConfig()])[0]
            assert replayed.stats.signature() == journal_engine.signature(), name

    def test_replay_batch_lockstep_resources(self):
        """(segments, runs) lockstep accumulation: every replayed run's
        final resource counters equal the compile-run's actual counters."""
        inst = build("mixed_stream", n=1 << 12)
        sim = inst.make_sim(engine="event")
        trace, _ = get_or_compile(sim)
        want_hbm = (sim.hbm.next_free_cycle, sim.hbm.total_bytes,
                    sim.hbm.total_rd_bytes, sim.hbm.total_wr_bytes)
        runs = replay_batch(trace, [SimConfig() for _ in range(5)])
        assert len(runs) == 5
        for res in runs:
            got = res.resources["hbm"]
            assert got == pytest.approx(want_hbm)
            assert res.resources["writebacks"] == sim.cache.writebacks
        # independent result objects: mutating one engine must not leak
        runs[0].stats.record(0, 0, 7, 1, None)
        assert runs[0].stats.signature() != runs[1].stats.signature()

    def test_replayed_sim_object_state(self):
        """After a cache-hit run, the simulator object is observably
        equivalent to one that simulated: stream bookkeeping closed out,
        bandwidth/writeback counters restored."""
        inst = build("producer_consumer", stages=2)
        ref_sim = inst.make_sim(engine="event")
        ref = ref_sim.run()
        inst.run(engine="compiled")  # compile
        hit_sim = inst.make_sim(engine="compiled")
        res = hit_sim.run()
        assert res.signature() == ref.signature()
        assert hit_sim.streams.pending() == 0
        assert hit_sim.streams.busy_streams() == ()
        assert hit_sim.hbm.total_bytes == ref_sim.hbm.total_bytes
        assert hit_sim.hbm.total_wr_bytes == ref_sim.hbm.total_wr_bytes
        assert hit_sim.cache.writebacks == ref_sim.cache.writebacks
        assert hit_sim._cycle == ref_sim._cycle

    def test_incremental_rerun_matches_event_engine(self):
        """run → launch more → run again (the cycle/event incremental
        pattern) must work on the compiled engine too: the resumed portion
        falls back to the event loop, bit-identical."""

        def staged(engine):
            sim = TPUSimulator(SimConfig(engine=engine))
            s = sim.create_stream()
            sim.launch(s.stream_id, KernelDesc(
                name="k1", trace=pointer_chase_trace(1 << 20, 48), dependent=True))
            sim.run()
            sim.launch(s.stream_id, KernelDesc(
                name="k2", trace=pointer_chase_trace(1 << 20, 48), dependent=True))
            return sim.run()

        assert staged("compiled").signature() == staged("event").signature()

    def test_resume_after_replay_restores_cache_state(self):
        """Resuming a *replayed* simulator must see the recorded VMEM
        residency (restored lazily), so a follow-up kernel re-reading the
        array HITs exactly as it does after a real simulation."""

        def staged(engine):
            sim = TPUSimulator(SimConfig(engine=engine))
            s = sim.create_stream()
            sim.launch(s.stream_id, KernelDesc(
                name="walk", trace=pointer_chase_trace(1 << 20, 64), dependent=True))
            sim.run()
            sim.launch(s.stream_id, KernelDesc(
                name="rewalk", trace=pointer_chase_trace(1 << 20, 64), dependent=True))
            return sim.run()

        ref = staged("event")
        staged("compiled")  # compile the single-kernel shape
        res = staged("compiled")  # replay, then resume
        assert res.signature() == ref.signature()

    def test_report_sinks_replayed(self):
        from repro.core.sinks import JSONSink
        import io

        inst = build("mps_like", tenants=2, kernels_each=1)
        buf_ref, buf_replay = io.StringIO(), io.StringIO()
        inst.run(engine="event", sinks=[JSONSink(buf_ref)])
        inst.run(engine="compiled")  # compile (no sinks)
        inst.run(engine="compiled", sinks=[JSONSink(buf_replay)])
        ref = [
            {k: v for k, v in obj.items() if k != "header"}
            for obj in JSONSink.parse(buf_ref.getvalue())
        ]
        got = [
            {k: v for k, v in obj.items() if k != "header"}
            for obj in JSONSink.parse(buf_replay.getvalue())
        ]
        # headers embed kernel uids (run-varying by design); all stat
        # content, stream ids and block matrices must match exactly
        assert [
            {k: v for k, v in o.items() if k not in ("fields",)} for o in ref
        ] == [
            {k: v for k, v in o.items() if k not in ("fields",)} for o in got
        ]


class TestVectorBackend:
    SWEEP = [
        BatchJob.make("l2_lat", dict(n_loads=64, n_streams=2)),
        BatchJob.make("l2_lat", dict(n_loads=64, n_streams=2)),  # duplicate shape
        BatchJob.make("mps_like", dict(tenants=2, kernels_each=2)),
        BatchJob.make("fork_join", dict(rounds=1, width=2)),
    ]

    def test_vector_bit_identical_to_serial(self):
        jobs = self.SWEEP + same_shape_jobs("producer_consumer", 3, dict(stages=2))
        serial = BatchRunner(jobs).run(parallel=False)
        vector = BatchRunner(jobs, backend="vector").run(parallel=False)
        assert serial.signature() == vector.signature()
        assert serial.oracle_failures() == vector.oracle_failures() == []

    def test_vector_pooled_bit_identical(self):
        jobs = self.SWEEP
        serial = BatchRunner(jobs).run(parallel=False)
        vector = BatchRunner(jobs, workers=2, backend="vector").run(parallel=True)
        assert serial.signature() == vector.signature()

    def test_vector_simulates_each_shape_once(self):
        jobs = same_shape_jobs("l2_lat", 6, dict(n_loads=64, n_streams=2))
        BatchRunner(jobs, backend="vector").run(parallel=False)
        assert TRACE_CACHE.compiles == 1  # one shape, six draws, one sim

    def test_full_registry_vector_sweep(self):
        jobs = sweep_jobs(engines=("event",))
        serial = BatchRunner(jobs).run(parallel=False)
        vector = BatchRunner(jobs, backend="vector").run(parallel=False)
        assert serial.signature() == vector.signature()

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError, match="unknown backend"):
            BatchRunner(self.SWEEP, backend="gpu")

    def test_group_key_semantics(self):
        base = BatchJob.make("l2_lat", dict(n_loads=64))
        value_only = BatchJob.make("l2_lat", dict(n_loads=64),
                                   config=dict(max_cycles=123456))
        structural = BatchJob.make("l2_lat", dict(n_loads=64),
                                   config=dict(hbm_latency=60))
        assert base.group_key() == value_only.group_key()
        assert base.group_key() != structural.group_key()
        assert set(dict(value_only.config)) <= VALUE_ONLY_CONFIG | {"max_cycles"}

    def test_job_config_applies(self):
        job = BatchJob.make("straggler", dict(short_kernels=2, fast_streams=2),
                            config=dict(stream_slowdown={1: 2.0}))
        cfg = job.sim_config()
        assert cfg.stream_slowdown == {1: 2.0}
        from repro.sim.batch import run_job

        plain = run_job(BatchJob.make("straggler",
                                      dict(short_kernels=2, fast_streams=2)))
        slowed = run_job(job)
        assert slowed["cycles"] > plain["cycles"]  # the override took effect
        assert slowed["config"] == {"stream_slowdown": {1: 2.0}}


def test_value_only_draws_share_one_shape():
    draws = value_only_draws(8, seed=3)
    assert len(draws) == 8
    assert all(set(d) <= VALUE_ONLY_CONFIG for d in draws)
    jobs = [BatchJob.make("deepbench", config=d) for d in draws]
    assert len({j.group_key() for j in jobs}) == 1
