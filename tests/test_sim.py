"""Simulator validation — the paper's §5 experiments as tests."""

import numpy as np
import pytest

from repro.core.stats import AccessOutcome, AccessType
from repro.sim import (
    KernelDesc,
    SimConfig,
    TPUSimulator,
    l2_lat_expected_counts,
    l2_lat_multistream,
    mixed_stream_workload,
    deepbench_like_workload,
    pointer_chase_trace,
)

R = AccessType.GLOBAL_ACC_R
HIT, MSHR, MISS = AccessOutcome.HIT, AccessOutcome.HIT_RESERVED, AccessOutcome.MISS


class TestL2Lat:
    """§5.1 — deterministic per-stream counts."""

    @pytest.mark.parametrize("n_streams,n_loads", [(4, 64), (2, 256), (8, 128)])
    def test_exact_counts(self, n_streams, n_loads):
        res = l2_lat_multistream(n_streams, n_loads)
        exp = l2_lat_expected_counts(n_streams, n_loads)
        agg = res.stats.aggregate()
        assert int(agg[R, MISS]) == exp["MISS"]
        assert int(agg[R, MSHR]) == exp["MSHR_HIT"]
        assert int(agg[R, HIT]) == exp["HIT"]
        # each stream observed exactly n_loads accesses
        for sid in res.stats.streams():
            assert res.stats.stream_matrix(sid)[R].sum() == n_loads

    def test_clean_equals_sum_tip(self):
        """The paper's central §5.1 equality."""
        res = l2_lat_multistream(4, 64)
        agg = res.stats.aggregate()
        for o in (HIT, MSHR, MISS):
            assert res.clean.get(R, o) == int(agg[R, o])
        assert res.clean.lost_updates == 0

    def test_serialized_converts_mshr_to_hits(self):
        conc = l2_lat_multistream(4, 64)
        ser = l2_lat_multistream(4, 64, serialize=True)
        ca, sa = conc.stats.aggregate(), ser.stats.aggregate()
        assert int(sa[R, MSHR]) == 0
        assert int(sa[R, HIT]) > int(ca[R, HIT])
        # total accesses identical across modes
        assert sa[R].sum() == ca[R].sum()

    def test_serialized_no_overlap(self):
        ser = l2_lat_multistream(3, 64, serialize=True)
        sids = ser.stats.streams()
        assert ser.timeline.overlap_cycles(sids[0], sids[1]) == 0

    def test_concurrent_kernel_flag(self):
        """-gpgpu_concurrent_kernel_sm unset behaves like serialization."""
        res = l2_lat_multistream(4, 64, concurrent=False)
        assert int(res.stats.aggregate()[R, MSHR]) == 0


class TestMixed:
    """§5.2 — clean undercount under concurrency."""

    def test_sum_tip_geq_clean_and_undercount(self):
        res = mixed_stream_workload(n_streams=3, n=1 << 14)
        agg = res.stats.aggregate().astype(np.int64)
        clean = res.clean.matrix().astype(np.int64)
        assert np.all(agg >= clean)
        assert res.clean.lost_updates > 0
        assert int(agg.sum()) == int(clean.sum()) + res.clean.lost_updates

    def test_stream_fifo_dependencies(self):
        res = mixed_stream_workload(n_streams=1, n=1 << 12)
        ivs = {name: (s, e) for _, _, s, e, name in res.timeline.intervals()}
        assert ivs["scale_k2"][0] >= ivs["saxpy_k1"][1]
        assert ivs["add_k4"][0] >= ivs["scale_k2"][1]

    def test_per_stream_totals_mode_invariant(self):
        """Same workload, concurrent vs serialized: per-stream access totals
        must be identical (only HIT↔MSHR classification may shift)."""
        a = mixed_stream_workload(n_streams=2, n=1 << 12)
        b = mixed_stream_workload(n_streams=2, n=1 << 12, serialize=True)
        for sid in a.stats.streams():
            assert a.stats.stream_matrix(sid).sum() == b.stats.stream_matrix(sid).sum()


class TestDeepBench:
    def test_invariants(self):
        res = deepbench_like_workload(n_streams=2, repeats=6)
        agg = res.stats.aggregate()
        per = {s: int(res.stats.stream_matrix(s).sum()) for s in res.stats.streams()}
        assert sum(per.values()) == int(agg.sum())
        assert len(per) == 2

    def test_identical_kernels_balanced(self):
        res = deepbench_like_workload(n_streams=2, repeats=4)
        per = [int(res.stats.stream_matrix(s).sum()) for s in res.stats.streams()]
        assert per[0] == per[1]


class TestResourceModel:
    def test_mshr_entry_exhaustion(self):
        cfg = SimConfig(mshr_entries=4, hbm_latency=500)
        sim = TPUSimulator(cfg)
        s = sim.create_stream()
        # 64 independent line-sized misses vs 4 MSHRs → entry-fail stalls
        from repro.sim.kernel_desc import streaming_trace

        sim.launch(s.stream_id, KernelDesc(name="k", trace=streaming_trace(0, 64 * 512, R)))
        res = sim.run()
        from repro.core.stats import FailOutcome

        assert res.stats(R, FailOutcome.MSHR_ENTRY_FAIL, True, s.stream_id) > 0

    def test_straggler_injection_slows_stream(self):
        base = l2_lat_multistream(2, 128)
        cfg = SimConfig(stream_slowdown={1: 4.0})
        slow = l2_lat_multistream(2, 128, config=cfg)
        d_base = base.timeline.get(1, base.timeline.kernels(1)[0][0]).duration
        d_slow = slow.timeline.get(1, slow.timeline.kernels(1)[0][0]).duration
        assert d_slow > 2 * d_base
        # the un-slowed stream's counts are unaffected
        assert slow.stats.stream_matrix(2)[R].sum() == base.stats.stream_matrix(2)[R].sum()

    def test_vmem_capacity_evictions(self):
        cfg = SimConfig(vmem_capacity=16 * 512)  # 16 lines only
        sim = TPUSimulator(cfg)
        s = sim.create_stream()
        trace = pointer_chase_trace(0, 64, load_size=8, stride=512) * 2  # 64 lines, walked twice
        sim.launch(s.stream_id, KernelDesc(name="k", trace=trace, dependent=True))
        res = sim.run()
        m = res.stats.stream_matrix(s.stream_id)
        # second pass misses again (working set exceeds capacity)
        assert int(m[R, MISS]) > 64

    def test_event_dependency_across_streams(self):
        sim = TPUSimulator(SimConfig())
        s1, s2 = sim.create_stream(), sim.create_stream()
        ev = sim.create_event()
        from repro.sim.kernel_desc import streaming_trace

        k1 = KernelDesc(name="prod", trace=streaming_trace(0, 64 * 512, R))
        k2 = KernelDesc(name="cons", trace=streaming_trace(1 << 22, 64 * 512, R))
        sim.launch(s1.stream_id, k1, record_events=[ev.event_id])
        sim.launch(s2.stream_id, k2, wait_events=[ev.event_id])
        res = sim.run()
        ivs = {name: (s, e) for _, _, s, e, name in res.timeline.intervals()}
        assert ivs["cons"][0] >= ivs["prod"][1]
