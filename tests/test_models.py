"""Per-architecture smoke tests (reduced configs): forward/train/prefill/
decode shape + finiteness + cross-path consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import (
    decode_step,
    forward,
    init_cache,
    init_params,
    model_defs,
    prefill,
    tree_size,
)
from repro.serve.cache_utils import transplant

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=32, with_labels=False, key=KEY):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if with_labels:
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.encdec:
        batch["enc_embeds"] = jax.random.normal(key, (B, 64, cfg.d_model), jnp.float32)
    if cfg.vision_tokens:
        batch["vision_embeds"] = jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model), jnp.float32
        )
    return batch


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_smoke_config(arch)
            params = init_params(model_defs(cfg), KEY, cfg.param_jdtype())
            cache[arch] = (cfg, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, arch_state):
    cfg, params = arch_state(arch)
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    logits, aux = jax.jit(lambda p, b: forward(cfg, p, b))(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))
    # padded vocab ids are masked out
    if cfg.padded_vocab != cfg.vocab_size:
        assert float(logits[..., cfg.vocab_size :].max()) <= -1e8


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_runs_and_no_nans(arch, arch_state):
    from repro.train.trainer import TrainConfig, make_train_step
    from repro.optim import adamw_init

    cfg, params = arch_state(arch)
    tcfg = TrainConfig(microbatches=1)
    opt = adamw_init(params)
    batch = make_batch(cfg, 2, 32, with_labels=True)
    step = jax.jit(make_train_step(cfg, tcfg))
    new_params, new_opt, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(new_opt["step"]) == 1
    # parameters actually moved
    moved = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(new_params))
    )
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_matches_forward(arch, arch_state):
    cfg, params = arch_state(arch)
    batch = make_batch(cfg, 2, 32)
    logits, _ = jax.jit(lambda p, b: forward(cfg, p, b))(params, batch)
    pre, _ = jax.jit(lambda p, b: prefill(cfg, p, b))(params, batch)
    np.testing.assert_allclose(
        np.asarray(pre), np.asarray(logits[:, -1]), atol=2e-4, rtol=1e-3
    )


# decode consistency on a representative subset (one per family) keeps CI fast
DECODE_ARCHS = [
    "deepseek-7b",            # dense GQA
    "jamba-1.5-large-398b",   # hybrid ssm+moe
    "deepseek-v2-lite-16b",   # MLA + MoE
    "whisper-medium",         # enc-dec cross-attention
    "paligemma-3b",           # prefix-LM VLM
    "mamba2-130m",            # pure SSM
]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch, arch_state):
    cfg, params = arch_state(arch)
    if cfg.moe is not None:
        # decode == forward only holds drop-free: GShard capacity is
        # sequence-context-dependent, so the last token can overflow an
        # expert's per-row capacity inside forward() yet never drops when
        # decoded alone (per-row C >= top_k).  capacity_factor = n_experts
        # makes per-row capacity exactly T*top_k — no drops either way.
        from dataclasses import replace

        cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    B, S = 2, 31
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    batch = make_batch(cfg, B, S)
    batch["tokens"] = toks[:, :S]
    full = dict(batch, tokens=toks)
    _, small = jax.jit(lambda p, b: prefill(cfg, p, b))(params, batch)
    vis = cfg.vision_tokens or 0
    big = init_cache(cfg, B, 64 + vis, enc_len=64 if cfg.encdec else 0)
    cache = transplant(big, small)
    pos = jnp.full((B,), S + vis, jnp.int32)
    dec, new_cache = jax.jit(lambda p, c, t, q: decode_step(cfg, p, c, t, q))(
        params, cache, toks[:, S], pos
    )
    ref, _ = jax.jit(lambda p, b: forward(cfg, p, b))(params, full)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref[:, -1]), atol=2e-4, rtol=1e-3)
    # cache structure preserved
    assert jax.tree_util.tree_structure(new_cache) == jax.tree_util.tree_structure(cache)


def test_decode_loop_variants_agree(arch_state):
    from dataclasses import replace

    cfg, params = arch_state("deepseek-7b")
    B, S = 2, 16
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    _, small = jax.jit(lambda p, b: prefill(cfg, p, b))(params, {"tokens": toks[:, :S]})
    big = init_cache(cfg, B, 48)
    cache = transplant(big, small)
    pos = jnp.full((B,), S, jnp.int32)
    outs = {}
    for loop in ("inplace", "scan"):
        c2 = replace(cfg, decode_loop=loop)
        outs[loop], _ = jax.jit(lambda p, c, t, q: decode_step(c2, p, c, t, q))(
            params, cache, toks[:, S], pos
        )
    np.testing.assert_allclose(
        np.asarray(outs["inplace"]), np.asarray(outs["scan"]), atol=1e-5, rtol=1e-5
    )


def test_param_counts_match_pool_spec():
    """Framework param accounting lands on the published model sizes."""
    import repro.configs as C

    expected = {
        "jamba-1.5-large-398b": 398e9,
        "deepseek-7b": 7e9,
        "qwen2-72b": 72e9,
        "phi3-medium-14b": 14e9,
        "gemma-7b": 8.5e9,
        "deepseek-v2-lite-16b": 15.7e9,
        "llama4-scout-17b-a16e": 109e9,
        "mamba2-130m": 0.13e9,
    }
    for arch, target in expected.items():
        n = C.get_config(arch).param_count()
        assert abs(n - target) / target < 0.12, (arch, n, target)


def test_superblock_structure_jamba():
    cfg = get_smoke_config("jamba-1.5-large-398b")
    # 8-layer superblock: attention only at offset 4; MoE every other layer
    assert cfg.superblock_period == 8
    kinds = [(cfg.layer_is_attn(i), cfg.layer_is_moe(i)) for i in range(8)]
    assert [k[0] for k in kinds] == [False] * 4 + [True] + [False] * 3
    assert [k[1] for k in kinds] == [False, True] * 4


def test_deterministic_init(arch_state):
    cfg = get_smoke_config("deepseek-7b")
    p1 = init_params(model_defs(cfg), jax.random.PRNGKey(7), cfg.param_jdtype())
    p2 = init_params(model_defs(cfg), jax.random.PRNGKey(7), cfg.param_jdtype())
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
