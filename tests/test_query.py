"""StatsFrame query-layer tests: equivalence with the legacy accessors,
lazy/zero-copy behaviour, name resolution, grouping/pivots/exports, the
timeline join (during / between_kernels / groupby("kernel")), and the
byte-identity of sink reports rendered through frames."""

import io

import numpy as np
import pytest

from repro.api import Session, simulate
from repro.core.engine import StatsEngine
from repro.core.query import EventJournal, QueryError, StatsFrame
from repro.core.sinks import frame_block, render_text, stream_report, Report, StatBlock
from repro.core.stats import AccessOutcome, AccessType, CleanStatTable, StatTable
from repro.sim.scenarios import build


# --------------------------------------------------------------------------- helpers
def _rand_engine(seed=0, n_events=4000, n_streams=5):
    rng = np.random.default_rng(seed)
    eng = StatsEngine()
    eng.record_batch(
        rng.integers(0, AccessType.count(), n_events),
        rng.integers(0, AccessOutcome.count(), n_events),
        rng.integers(0, n_streams, n_events),
        rng.integers(1, 5, n_events).astype(np.uint64),
        np.cumsum(rng.random(n_events) < 0.4).astype(np.int64),
    )
    eng.record_batch(
        rng.integers(0, AccessType.count(), 200),
        rng.integers(0, 4, 200),
        rng.integers(0, n_streams, 200),
        fail=True,
    )
    return eng


# --------------------------------------------------------------------------- accessors
def test_matrix_matches_stream_matrix_all_views():
    eng = _rand_engine()
    f = StatsFrame(eng)
    for sid in eng.streams():
        assert np.array_equal(f.filter(stream=sid).matrix(), eng.stream_matrix(sid))
        assert np.array_equal(
            f.filter(stream=sid, view="pw").matrix(), eng.stream_matrix(sid, pw=True)
        )
        assert np.array_equal(
            f.filter(stream=sid, view="fail").matrix(), eng.stream_matrix(sid, fail=True)
        )
        # the frame-native single-stream accessor too
        assert np.array_equal(f.stream_matrix(sid), eng.stream_matrix(sid))
        assert np.array_equal(f.stream_matrix(sid, view="fail"), eng.stream_matrix(sid, fail=True))


def test_aggregate_and_sum():
    eng = _rand_engine()
    f = StatsFrame(eng)
    assert np.array_equal(f.matrix(), eng.aggregate())
    assert f.sum() == int(eng.aggregate().sum())
    assert f.filter(view="fail").sum() == int(eng.aggregate(fail=True).sum())


def test_unknown_stream_is_zero():
    eng = _rand_engine()
    f = StatsFrame(eng)
    assert f.filter(stream=999).sum() == 0
    assert np.array_equal(f.stream_matrix(999), np.zeros_like(eng.stream_matrix(999)))


def test_axis_filters_and_intersection():
    eng = _rand_engine()
    f = StatsFrame(eng)
    m = eng.aggregate()
    t = int(AccessType.GLOBAL_ACC_R)
    o = int(AccessOutcome.MISS)
    assert f.filter(access_type=t, outcome=o).sum() == int(m[t, o])
    assert f.filter(access_type="GLOBAL_ACC_R").filter(outcome="MISS").sum() == int(m[t, o])
    # intersecting disjoint selections -> empty
    assert f.filter(stream=0).filter(stream=1).sum() == 0
    # outcome display names (paper labels) and enum names both resolve
    assert (
        f.filter(outcome="MSHR_HIT").sum()
        == f.filter(outcome="HIT_RESERVED").sum()
        == int(m[:, AccessOutcome.HIT_RESERVED].sum())
    )


def test_name_resolution_and_errors():
    eng = _rand_engine()
    f = StatsFrame(eng, names={"alpha": 0, "beta": 1})
    assert f.filter(stream="alpha").sum() == f.filter(stream=0).sum()
    assert f.stream_label(1) == "beta"
    assert f.stream_label(3) == 3
    with pytest.raises(QueryError):
        f.filter(stream="gamma")
    with pytest.raises(QueryError):
        f.filter(access_type="NOT_A_TYPE")
    with pytest.raises(QueryError):
        f.filter(outcome="NOT_AN_OUTCOME")
    with pytest.raises(QueryError):
        f.filter(view="bogus")
    with pytest.raises(QueryError):
        f.groupby("bogus")


def test_stream_matrix_view_override_drops_cross_axis_outcome_filter():
    # regression: an AccessOutcome filter must not mask FailOutcome columns
    # when the view= override crosses the tip/fail axis boundary
    eng = StatsEngine()
    eng.record(0, int(AccessOutcome.MISS), 1, 5, 10)
    eng.record_fail(0, 0, 1, 3, 11)
    f = StatsFrame(eng).filter(outcome="MISS")
    assert np.array_equal(f.stream_matrix(1, view="fail"), eng.stream_matrix(1, fail=True))
    assert int(f.stream_matrix(1, view="fail").sum()) == 3
    # the same-axis filter still applies
    assert int(f.stream_matrix(1).sum()) == 5
    # and through a cycle window too
    ej = EventJournal()
    ej.record(0, int(AccessOutcome.MISS), 1, 5, 10)
    ej.record_fail(0, 0, 1, 3, 11)
    wf = StatsFrame(ej).filter(outcome="MISS").between_cycles(0, 20)
    assert int(wf.stream_matrix(1, view="fail").sum()) == 3


def test_fail_view_outcome_names():
    eng = _rand_engine()
    f = StatsFrame(eng, view="fail")
    agg = eng.aggregate(fail=True)
    assert f.filter(outcome="MSHR_ENTRY_FAIL").sum() == int(agg[:, 1].sum())
    # switching view families drops the (incompatible) outcome filter
    assert f.filter(outcome="MSHR_ENTRY_FAIL").filter(view="tip").sum() == StatsFrame(eng).sum()


def test_stream_filtered_frame_rejects_clean_view_switch():
    # regression: a retained stream filter must not silently serve tip data
    # relabeled as the (streamless) clean lanes
    eng = _rand_engine()
    f = StatsFrame(eng)
    for clean_view in ("clean", "clean_fail"):
        with pytest.raises(QueryError):
            f.filter(stream=0).filter(view=clean_view)


def test_outcome_counts_rejects_fail_views():
    # regression: AccessOutcome column indices into a FailOutcome axis are
    # silently meaningless — must raise instead
    eng = _rand_engine()
    with pytest.raises(QueryError):
        StatsFrame(eng).filter(view="fail").outcome_counts()
    with pytest.raises(QueryError):
        StatsFrame(eng, view="clean_fail").outcome_counts()


def test_clean_views():
    eng = _rand_engine()
    f = StatsFrame(eng)
    assert np.array_equal(f.filter(view="clean").matrix(), eng.clean.matrix())
    assert f.filter(view="clean").sum() == int(eng.clean.matrix().sum())
    assert f.filter(view="clean_fail").sum() == int(eng.clean_fail.matrix().sum())
    with pytest.raises(QueryError):
        f.filter(view="clean", stream=0)
    # CleanStatTable as a direct source
    ct = CleanStatTable()
    ct.inc_stats(0, 2, cycle=5, stream_id=1, n=3)
    cf = StatsFrame(ct, view="clean")
    assert cf.sum() == 3


def test_stat_table_source():
    t = StatTable()
    t.inc_stats(0, 2, 7, 5)
    t.inc_stats_pw(0, 2, 7, 5)
    t.inc_fail_stats(1, 0, 7, 2)
    f = StatsFrame(t, names={"s": 7})
    assert f.filter(stream="s").sum() == 5
    assert f.filter(view="pw").sum() == 5
    assert f.filter(view="fail").sum() == 2
    assert np.array_equal(f.stream_matrix("s"), t.stream_matrix(7))


# --------------------------------------------------------------------------- laziness / zero-copy
def test_values_zero_copy_and_readonly():
    eng = _rand_engine()
    f = StatsFrame(eng)
    v = f.values
    assert np.shares_memory(v, eng._cum)
    assert not v.flags.writeable
    with pytest.raises(ValueError):
        v[0, 0, 0] = 1
    one = f.filter(stream=eng.streams()[0])
    assert np.shares_memory(one.values, eng._cum)
    assert one.values.shape[0] == 1
    assert np.shares_memory(f.filter(view="pw").values, eng._pw)
    assert np.shares_memory(f.filter(view="fail").values, eng._fail)
    # axis filters can't be expressed as a raw store view — refuse rather
    # than silently return unfiltered data (regression)
    with pytest.raises(QueryError):
        f.filter(outcome="MISS").values
    with pytest.raises(QueryError):
        f.filter(access_type="GLOBAL_ACC_R").values


def test_frames_are_lazy_live_views():
    eng = StatsEngine()
    eng.record(0, 2, 1, 1, 0)
    f = StatsFrame(eng).filter(stream=1, outcome="MISS")
    assert f.sum() == 1
    eng.record(0, 2, 1, 4, 1)  # frame built *before* this event
    assert f.sum() == 5  # lazy: reads current engine state


def test_filter_does_not_mutate_parent():
    eng = _rand_engine()
    f = StatsFrame(eng)
    total = f.sum()
    sub = f.filter(stream=0, access_type=0, outcome=2)
    assert f.sum() == total
    assert sub.sum() <= total


# --------------------------------------------------------------------------- grouping / export
def test_groupby_sums():
    eng = _rand_engine()
    f = StatsFrame(eng, names={"a": 0})
    by_stream = f.groupby("stream").sum()
    assert sum(by_stream.values()) == f.sum()
    assert by_stream["a"] == f.filter(stream=0).sum()
    by_outcome = f.groupby("outcome").sum()
    assert sum(by_outcome.values()) == f.sum()
    assert by_outcome["MISS"] == f.filter(outcome="MISS").sum()
    by_type = f.groupby("access_type").sum()
    assert sum(by_type.values()) == f.sum()
    # groupby on a filtered frame only yields the selected groups
    assert list(f.filter(outcome="MISS").groupby("outcome").sum()) == ["MISS"]


def test_pivot():
    eng = _rand_engine()
    f = StatsFrame(eng, names={"a": 0, "b": 1})
    rows, cols, table = f.pivot(rows="stream", cols="outcome")
    assert table.sum() == f.sum()
    r = rows.index("a")
    c = cols.index("MISS")
    assert table[r, c] == f.filter(stream="a", outcome="MISS").sum()
    with pytest.raises(QueryError):
        f.pivot(rows="stream", cols="stream")


def test_pivot_kernel_axis_unions_columns():
    # regression: row groups exposing different columns (each stream owns
    # different kernels) must union, not KeyError on the first row's labels
    res = simulate("producer_consumer", stages=2, keep_events=True)
    rows, cols, table = res.frame.pivot(rows="stream", cols="kernel")
    assert set(cols) == {"produce_0", "produce_1", "consume_0", "consume_1"}
    assert table.sum() == res.frame.sum()
    p = rows.index("producer")
    assert table[p, cols.index("consume_0")] == 0  # not the producer's kernel
    # and the transposed orientation works too
    rows2, cols2, table2 = res.frame.pivot(rows="kernel", cols="stream")
    assert table2.sum() == res.frame.sum()


def test_to_dict_and_csv():
    eng = StatsEngine()
    eng.record(int(AccessType.GLOBAL_ACC_R), int(AccessOutcome.MISS), 3, 7, 1)
    f = StatsFrame(eng, names={"s3": 3})
    d = f.to_dict()
    assert d == {"s3": {"GLOBAL_ACC_R": {"MISS": 7}}}
    csv_text = f.to_csv()
    assert "view,stream,access_type,outcome,count" in csv_text
    assert "tip,s3,GLOBAL_ACC_R,MISS,7" in csv_text


def test_outcome_counts_matches_oracle_math():
    res = build("l2_lat", n_streams=3, n_loads=64).run(engine="event")
    inst = build("l2_lat", n_streams=3, n_loads=64)
    frame = inst.frame(res)
    for sname, sid in inst.stream_ids.items():
        if sname == "":
            continue
        m = res.stats.stream_matrix(sid)
        got = frame.filter(stream=sname).outcome_counts()
        assert got["HIT"] == int(m[:, AccessOutcome.HIT].sum())
        assert got["MSHR_HIT"] == int(m[:, AccessOutcome.HIT_RESERVED].sum())
        assert got["MISS"] == int(m[:, AccessOutcome.MISS].sum())
        assert got["TOTAL"] == got["HIT"] + got["MSHR_HIT"] + got["MISS"]


# --------------------------------------------------------------------------- timeline join
def test_event_journal_counts_identical_to_plain_engine():
    res_plain = simulate("producer_consumer", stages=2)
    res_events = simulate("producer_consumer", stages=2, keep_events=True)
    assert res_plain.signature() == res_events.signature()


def test_during_kernel():
    res = simulate("producer_consumer", stages=2, keep_events=True)
    f = res.frame
    # each producer kernel writes stage_lines MISSes during its own window
    assert f.during("produce_0").filter(outcome="MISS").sum() == 32
    assert f.during("consume_1").filter(outcome="HIT").sum() == 32
    # stream filter composes with the window
    assert f.during("produce_0").filter(stream="consumer").sum() == 0


def test_groupby_kernel_partitions_stream_totals():
    res = simulate("producer_consumer", stages=3, keep_events=True)
    f = res.frame
    per_kernel = f.groupby("kernel").sum()
    assert set(per_kernel) == {
        "produce_0", "produce_1", "produce_2", "consume_0", "consume_1", "consume_2",
    }
    prod_total = sum(v for k, v in per_kernel.items() if k.startswith("produce"))
    assert prod_total == f.filter(stream="producer").sum()
    assert sum(per_kernel.values()) == f.sum()


def test_groupby_kernel_honors_stream_filter():
    # regression: a stream-filtered frame must not report phantom
    # zero-count groups for other streams' kernels
    res = simulate("producer_consumer", stages=2, keep_events=True)
    per_kernel = res.frame.filter(stream="producer").groupby("kernel").sum()
    assert set(per_kernel) == {"produce_0", "produce_1"}
    assert per_kernel["produce_0"] == 32


def test_between_kernels_excludes_both():
    res = simulate("producer_consumer", stages=2, keep_events=True)
    f = res.frame
    gap = f.between_kernels("produce_0", "consume_1", stream=None)
    # everything in the gap on the producer stream is produce_1's work
    w0 = f.kernel_window("produce_0")
    w1 = f.kernel_window("consume_1")
    manual = f.between_cycles(w0[1] + 1, w1[0] - 1).filter(stream="producer").sum()
    assert gap.filter(stream="producer").sum() == manual


def test_window_queries_require_events_and_timeline():
    res = simulate("producer_consumer", stages=2)  # no keep_events
    with pytest.raises(QueryError):
        res.frame.during("produce_0")
    eng = _rand_engine()
    with pytest.raises(QueryError):
        StatsFrame(eng).kernels()  # no timeline
    ej = EventJournal()
    ej.record(0, 2, 1, 1, 5)
    with pytest.raises(QueryError):  # clean lanes cannot be windowed
        StatsFrame(ej).between_cycles(0, 10).filter(view="clean").sum()


def test_windowed_stream_matrix_honors_stream_filter():
    # regression: a windowed frame's stream_matrix must not leak a
    # filtered-out stream's counts (same zeros as the un-windowed path)
    res = simulate("producer_consumer", stages=2, keep_events=True)
    prod = res.stream_ids["producer"]
    cons = res.stream_ids["consumer"]
    f = res.frame.filter(stream=prod).between_cycles(0, res.cycles)
    assert f.stream_matrix(cons).sum() == 0
    assert np.array_equal(
        res.frame.filter(stream=prod).stream_matrix(cons),
        np.zeros_like(res.frame.stream_matrix(cons)),
    )
    # the selected stream still reads through the window
    assert f.stream_matrix(prod).sum() == res.frame.filter(stream=prod).sum()


def test_windowed_matrix_matches_manual_event_math():
    ej = EventJournal()
    ej.record(0, 2, 1, 5, 10)
    ej.record(0, 2, 1, 3, 20)
    ej.record(1, 0, 2, 7, 15)
    ej.inc_stats(0, 2, 1, 100)  # no cycle -> never inside a window
    f = StatsFrame(ej)
    w = f.between_cycles(10, 15)
    assert w.sum() == 12
    assert w.filter(stream=1).sum() == 5
    assert f.between_cycles(0, 9).sum() == 0
    # window on pw view sees the same events
    assert f.filter(view="pw").between_cycles(10, 15).sum() == 12


# --------------------------------------------------------------------------- sink integration
def test_stream_report_byte_identical_to_legacy_report():
    res = build("deepbench").run(engine="event")
    eng = res.stats
    for sid in eng.streams():
        legacy = Report(
            source="sim",
            event="kernel_exit",
            stream_id=sid,
            blocks=[
                StatBlock("Total_core_cache_stats", eng.stream_matrix(sid)),
                StatBlock(
                    "Total_core_cache_fail_stats",
                    eng.stream_matrix(sid, fail=True),
                    fail=True,
                ),
            ],
        )
        framed = stream_report(
            StatsFrame(eng, timeline=res.timeline),
            sid,
            source="sim",
            event="kernel_exit",
            cache_name="Total_core_cache_stats",
            fail_cache_name="Total_core_cache_fail_stats",
        )
        assert render_text(framed) == render_text(legacy)


def test_frame_block_marks_fail_axis():
    eng = _rand_engine()
    f = StatsFrame(eng)
    b = frame_block(f, "X", stream=0, view="fail")
    assert b.fail and np.array_equal(b.matrix, eng.stream_matrix(0, fail=True))
    b2 = frame_block(f, "X", stream=0)
    assert not b2.fail


def test_legacy_print_stats_equals_frame_render():
    res = build("deepbench").run(engine="event")
    eng = res.stats
    sid = eng.streams()[0]
    buf = io.StringIO()
    eng.print_stats(buf, sid)
    legacy = buf.getvalue()
    from repro.core.stats import format_breakdown

    framed = format_breakdown(eng.name, sid, StatsFrame(eng).stream_matrix(sid))
    assert framed == legacy


# --------------------------------------------------------------------------- Session
def test_session_launch_and_query():
    s = Session(engine="event", keep_events=True)
    s.stream("hi", priority=1)
    s.launch("hi", rd_bytes=64 * 512, name="a0", record="e0")
    s.launch("lo", wr_bytes=32 * 512, name="b0", wait="e0")
    res = s.run()
    assert res.frame.groupby("stream").sum() == {"hi": 64, "lo": 32}
    assert res.frame.during("b0").filter(stream="lo").sum() == 32
    # event wiring: b0 starts after a0 ends
    assert res.frame.kernel_window("b0")[0] >= res.frame.kernel_window("a0")[1]
    # a session runs once; a second run() returns the same result
    assert s.run() is res
    with pytest.raises(RuntimeError):
        s.launch("hi", rd_bytes=512)


def test_session_rejects_unknown_config_field():
    with pytest.raises(TypeError):
        Session(not_a_field=1)


def test_session_launch_rejects_kernel_plus_builder_keywords():
    # regression: builder keywords alongside kernel= were silently dropped
    from repro.api import KernelDesc

    s = Session()
    kd = KernelDesc(name="k", hbm_rd_bytes=512, addr_base=1 << 20)
    with pytest.raises(TypeError, match="rd_bytes"):
        s.launch("a", kernel=kd, rd_bytes=1 << 20)
    with pytest.raises(TypeError, match="name"):
        s.launch("a", kernel=kd, name="other")
    s.launch("a", kernel=kd)  # prebuilt alone is fine


def test_session_rejects_conflicting_stream_priority():
    # regression: a priority on an already-created stream cannot bind — fail
    # loudly (the ScenarioInstance launch-row rule, imperative flavour)
    s = Session()
    s.launch("worker", rd_bytes=512)  # auto-creates "worker" at priority 0
    with pytest.raises(ValueError):
        s.stream("worker", priority=1)
    assert s.stream("worker") == s.stream("worker", priority=0)  # same value ok
