"""Fault-injection subsystem (docs/DESIGN.md §5.11).

The contracts under test, layer by layer:

* **kernel** — for any seeded :class:`FaultPlan`, (a) the conservation
  oracle holds (every spec resolves exactly once: ``KERNEL_ABORT`` or
  ``RECOVERED``), (b) the cycle and event engines stay signature-identical,
  and (c) a fault-off config is bit-identical to the pre-subsystem goldens.
* **serve** — queue-overflow shedding, bounded retry/backoff, deadlines and
  cancellation keep their own ledger (``SHED == terminal sheds + RETRY +
  cancellations``; ``RECOVERED`` counts exactly the requests that finished
  despite a fault), and ``run_until_idle`` refuses to livelock.
* **pool** — the fault schedule is a pure function of (job index, attempt),
  so pooled and serial sweeps fail and recover bit-identically; a killed
  sweep resumes from its journal bit-identically.
"""

import os
import pickle

import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
except ModuleNotFoundError:
    # hypothesis is a dev-only dependency (requirements-dev.txt).  Without it
    # the property tests skip but the deterministic tests below still run.
    def settings(*_a, **_k):
        return lambda fn: fn

    def given(*_a, **_k):
        return lambda fn: pytest.mark.skip(reason="hypothesis not installed")(fn)

    class _AnyStrategy:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    class HealthCheck:
        too_slow = None

from repro.core.faults import (
    FAULT_KINDS,
    FAULT_LANES,
    FaultPlan,
    KernelFaultSpec,
    check_sim_conservation,
)
from repro.sim.batch import BatchRunner, sweep_jobs
from repro.sim.executor import SimConfig
from repro.sim.scenarios import build

# --------------------------------------------------------------------- helpers

#: pre-subsystem golden cycle counts (test_scenarios.GOLDEN_CYCLES excerpt):
#: fault-plan-off must reproduce these bit-for-bit on every engine.
FAULT_OFF_GOLDENS = {"cache_thrash": 9602, "mixed_stream": 240, "straggler": 512}


def _run(scenario, engine, plan=None, **params):
    inst = build(scenario, **params)
    cfg = SimConfig()
    if plan is not None:
        cfg.fault_plan = plan
    return inst.run(engine=engine, config=cfg)


def _mixed_plan(seed=0):
    return FaultPlan(seed=seed, kernel_faults=(
        KernelFaultSpec("abort", stream=1, kernel=0, after=40),
        KernelFaultSpec("slowdown", stream=2, kernel=0, after=10,
                        duration=150, factor=3.0),
        KernelFaultSpec("hbm_stall", stream=1, after=25, duration=80),
        KernelFaultSpec("abort", stream=3, kernel=5, after=10),
    ))


# ---------------------------------------------------------------- kernel layer
class TestKernelFaults:
    @pytest.mark.parametrize("scenario", sorted(FAULT_OFF_GOLDENS))
    @pytest.mark.parametrize("engine", ["cycle", "event", "compiled"])
    def test_fault_off_bit_identity_vs_goldens(self, scenario, engine):
        """No plan, and an empty plan, both reproduce the pre-subsystem
        goldens exactly — the subsystem is invisible when off."""
        bare = _run(scenario, engine)
        empty = _run(scenario, engine, plan=FaultPlan())
        assert bare.cycles == FAULT_OFF_GOLDENS[scenario]
        assert bare.signature() == empty.signature()

    @pytest.mark.parametrize("scenario", ["cache_thrash", "mixed_stream", "straggler"])
    def test_engine_identity_and_conservation_under_plan(self, scenario):
        plan = _mixed_plan()
        res = {e: _run(scenario, e, plan=plan) for e in ("cycle", "event", "compiled")}
        assert res["cycle"].signature() == res["event"].signature()
        assert res["event"].signature() == res["compiled"].signature()
        check = check_sim_conservation(res["event"], plan)
        assert check["ok"], check["mismatches"]

    def test_abort_kills_work(self):
        off = _run("cache_thrash", "event")
        on = _run("cache_thrash", "event",
                  plan=FaultPlan(kernel_faults=(
                      KernelFaultSpec("abort", stream=1, kernel=0, after=5),)))
        assert on.cycles < off.cycles
        counts = on.frame.filter(stream=1).outcome_counts()
        assert counts["KERNEL_ABORT"] == 1
        assert counts["TOTAL"] < off.frame.filter(stream=1).outcome_counts()["TOTAL"]

    def test_never_launched_target_recovers(self):
        """A spec aimed at a kernel that never launches must still resolve
        (RECOVERED at end-of-sim) — conservation has no leaks."""
        plan = FaultPlan(kernel_faults=(
            KernelFaultSpec("abort", stream=7, kernel=99, after=10),))
        res = _run("cache_thrash", "event", plan=plan)
        check = check_sim_conservation(res, plan)
        assert check["ok"], check["mismatches"]
        assert check["per_stream"][7]["RECOVERED"] == 1

    def test_plan_is_structural(self):
        a, b = SimConfig(), SimConfig()
        b.fault_plan = _mixed_plan()
        assert a.structural_key() != b.structural_key()
        c = SimConfig()
        c.fault_plan = _mixed_plan()
        assert b.structural_key() == c.structural_key()

    @settings(max_examples=12, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(specs=st.lists(
        st.builds(
            KernelFaultSpec,
            kind=st.sampled_from(FAULT_KINDS),
            stream=st.integers(min_value=1, max_value=3),
            kernel=st.integers(min_value=0, max_value=3),
            after=st.integers(min_value=0, max_value=3000),
            duration=st.integers(min_value=0, max_value=400),
            factor=st.floats(min_value=1.5, max_value=8.0),
        ),
        min_size=1, max_size=5,
    ), seed=st.integers(min_value=0, max_value=2**32 - 1))
    def test_random_plans_conserve_and_agree(self, specs, seed):
        plan = FaultPlan(seed=seed, kernel_faults=tuple(specs))
        cyc = _run("mixed_stream", "cycle", plan=plan)
        evt = _run("mixed_stream", "event", plan=plan)
        assert cyc.signature() == evt.signature()
        check = check_sim_conservation(evt, plan)
        assert check["ok"], check["mismatches"]


# ------------------------------------------------------------------ pool layer
JOBS = lambda: sweep_jobs(  # noqa: E731 - fresh list per test
    scenarios=["l2_lat", "cache_thrash", "mixed_stream"], engines=("event",))


class TestPoolFaults:
    def test_pooled_equals_serial_under_faults(self):
        plan = FaultPlan(seed=1, crash_jobs=(0,), hang_jobs=(2,),
                         fail_attempts=1, pool_max_retries=2, job_timeout_s=2.0)
        jobs = JOBS()
        par = BatchRunner(jobs, workers=2, fault_plan=plan).run(parallel=True)
        ser = BatchRunner(jobs, workers=2, fault_plan=plan).run(parallel=False)
        assert par.signature() == ser.signature()
        assert not par.failures()
        assert [p["attempts"] for p in par.payloads] == [2, 1, 2]
        fr = par.frame()
        assert int(fr.filter(outcome="RETRY").sum()) == 2
        assert int(fr.filter(outcome="RECOVERED").sum()) == 2

    def test_retry_exhaustion_degrades_gracefully(self):
        plan = FaultPlan(seed=1, crash_jobs=(1,), fail_attempts=10,
                         pool_max_retries=1, job_timeout_s=2.0)
        jobs = JOBS()
        par = BatchRunner(jobs, workers=2, fault_plan=plan).run(parallel=True)
        ser = BatchRunner(jobs, workers=2, fault_plan=plan).run(parallel=False)
        assert par.signature() == ser.signature()
        assert [f["job_index"] for f in par.failures()] == [1]
        assert par.payloads[1]["failed"] and par.payloads[1]["attempts"] == 2
        assert int(par.frame().filter(outcome="SHED").sum()) == 1
        # surviving jobs still merged and queryable
        assert par.payloads[0]["oracle"]["ok"]
        with pytest.raises(ValueError, match="failed after"):
            par.job_frame(1)

    def test_journal_resume_bit_identical(self, tmp_path):
        plan = FaultPlan(seed=1, crash_jobs=(0,), fail_attempts=1,
                         pool_max_retries=2, job_timeout_s=5.0)
        jobs = JOBS()
        journal = str(tmp_path / "sweep.journal")
        ref = BatchRunner(jobs, workers=2, fault_plan=plan,
                          journal=journal).run(parallel=True)
        full = open(journal, "rb").read()
        # simulate a mid-sweep kill: header + first payload + a torn record
        with open(journal, "rb") as fh:
            pickle.load(fh)  # header
            pickle.load(fh)  # first payload
            cut = fh.tell()
        with open(journal, "wb") as fh:
            fh.write(full[:cut])
            fh.write(b"\x80\x04torn-tail")
        resumed = BatchRunner(jobs, workers=2, fault_plan=plan,
                              journal=journal).run(parallel=True)
        assert resumed.signature() == ref.signature()

    def test_stale_journal_ignored(self, tmp_path):
        journal = str(tmp_path / "sweep.journal")
        jobs = JOBS()
        BatchRunner(jobs, workers=2, journal=journal).run(parallel=False)
        other = sweep_jobs(scenarios=["l2_lat"], engines=("event",))
        res = BatchRunner(other, workers=1, journal=journal).run(parallel=False)
        ref = BatchRunner(other, workers=1).run(parallel=False)
        assert res.signature() == ref.signature()

    def test_vector_backend_rejects_armed_pool_faults(self):
        # An unarmed plan is a no-op everywhere, so the vector backend
        # accepts it; only pool-layer schedules (crash/hang/fail) require
        # the process pool.
        jobs = JOBS()
        ref = BatchRunner(jobs).run(parallel=False)
        res = BatchRunner(jobs, backend="vector",
                          fault_plan=FaultPlan()).run()
        assert res.signature() == ref.signature()
        with pytest.raises(ValueError, match="backend='pool'"):
            BatchRunner(JOBS(), backend="vector",
                        fault_plan=FaultPlan(seed=1, crash_jobs=(0,)))

    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(seed=st.integers(min_value=0, max_value=2**32 - 1),
           job=st.integers(min_value=0, max_value=63),
           attempt=st.integers(min_value=0, max_value=4))
    def test_schedules_are_pure_functions(self, seed, job, attempt):
        """Same seed ⇒ identical schedule wherever it is evaluated — the
        property the pooled==serial identity rests on."""
        a = FaultPlan(seed=seed, crash_jobs=(1, 5), hang_jobs=(2,),
                      fail_attempts=2, backoff_jitter=7)
        b = FaultPlan(seed=seed, crash_jobs=(1, 5), hang_jobs=(2,),
                      fail_attempts=2, backoff_jitter=7)
        assert a.pool_fault(job, attempt) == b.pool_fault(job, attempt)
        assert a.backoff_steps(attempt, job) == b.backoff_steps(attempt, job)
        assert 0 <= a.jitter(job, attempt) <= 7
        assert a.backoff_steps(attempt, job) >= a.backoff_base * 2 ** attempt
