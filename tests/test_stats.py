"""Unit + property tests for the paper's core: per-stream stat tables."""

import io

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # hypothesis is a dev-only dependency (requirements-dev.txt).  Without it
    # the property tests skip but the unit tests below still run.
    def settings(*_a, **_k):
        return lambda fn: fn

    def given(*_a, **_k):
        return lambda fn: pytest.mark.skip(reason="hypothesis not installed")(fn)

    class _AnyStrategy:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()

from repro.core.stats import (
    AccessOutcome,
    AccessType,
    CleanStatTable,
    StatTable,
)

R = AccessType.GLOBAL_ACC_R
W = AccessType.GLOBAL_ACC_W
HIT = AccessOutcome.HIT
MISS = AccessOutcome.MISS


class TestStatTable:
    def test_lazy_per_stream_allocation(self):
        t = StatTable()
        assert t.streams() == ()
        t.inc_stats(R, HIT, stream_id=3)
        t.inc_stats(R, HIT, stream_id=7)
        assert t.streams() == (3, 7)

    def test_inc_and_accessor(self):
        t = StatTable()
        t.inc_stats(R, MISS, 1)
        t.inc_stats(R, MISS, 1, n=4)
        assert t(R, MISS, False, 1) == 5
        assert t(R, MISS, False, 2) == 0  # unknown stream reads as zero

    def test_per_window_independent(self):
        t = StatTable()
        t.inc_stats(R, HIT, 1)
        t.inc_stats_pw(R, HIT, 1)
        t.clear_pw()
        assert t.get(R, HIT, 1) == 1
        assert t.stream_matrix(1, pw=True).sum() == 0

    def test_fail_stats_separate(self):
        from repro.core.stats import FailOutcome

        t = StatTable()
        t.inc_fail_stats(R, FailOutcome.MSHR_ENTRY_FAIL, 2)
        assert t(R, FailOutcome.MSHR_ENTRY_FAIL, True, 2) == 1
        assert t.stream_matrix(2).sum() == 0  # not mixed into access stats

    def test_aggregate_is_sum_over_streams(self):
        t = StatTable()
        t.inc_stats(R, HIT, 1, n=10)
        t.inc_stats(R, HIT, 2, n=32)
        t.inc_stats(W, MISS, 2, n=5)
        agg = t.aggregate()
        assert agg[R, HIT] == 42
        assert agg[W, MISS] == 5

    def test_print_only_given_stream(self):
        t = StatTable()
        t.inc_stats(R, HIT, 1, n=3)
        t.inc_stats(R, HIT, 2, n=9)
        buf = io.StringIO()
        t.print_stats(buf, 1)
        out = buf.getvalue()
        assert "= 3" in out and "= 9" not in out and "stream 1" in out

    def test_merge(self):
        a, b = StatTable(), StatTable()
        a.inc_stats(R, HIT, 1, n=2)
        b.inc_stats(R, HIT, 1, n=3)
        b.inc_stats(R, MISS, 4, n=7)
        a.merge(b)
        assert a.get(R, HIT, 1) == 5
        assert a.get(R, MISS, 4) == 7

    def test_serde_roundtrip(self):
        t = StatTable()
        t.inc_stats(R, HIT, 1, n=2)
        t.inc_stats_pw(W, MISS, 9, n=6)
        t2 = StatTable.from_dict(t.to_dict())
        assert np.array_equal(t2.stream_matrix(1), t.stream_matrix(1))
        assert np.array_equal(t2.stream_matrix(9, pw=True), t.stream_matrix(9, pw=True))

    @settings(max_examples=50, deadline=None)
    @given(
        st.lists(
            st.tuples(
                st.integers(0, AccessType.count() - 1),
                st.integers(0, AccessOutcome.count() - 1),
                st.integers(0, 5),  # stream
                st.integers(1, 100),  # n
            ),
            max_size=60,
        )
    )
    def test_property_aggregate_equals_manual_sum(self, events):
        t = StatTable()
        manual = {}
        for at, o, s, n in events:
            t.inc_stats(at, o, s, n)
            manual[(at, o)] = manual.get((at, o), 0) + n
        agg = t.aggregate()
        for (at, o), v in manual.items():
            assert int(agg[at, o]) == v
        # per-stream totals sum to aggregate total
        assert sum(t.total_accesses(s) for s in t.streams()) == int(agg.sum())


class TestCleanStatTable:
    def test_single_stream_never_loses(self):
        c = CleanStatTable()
        for cyc in (1, 1, 1, 2):
            c.inc_stats(R, HIT, cycle=cyc, stream_id=0)
        assert c.get(R, HIT) == 4
        assert c.lost_updates == 0

    def test_cross_stream_same_cycle_loses(self):
        c = CleanStatTable()
        c.inc_stats(R, HIT, cycle=5, stream_id=0)
        c.inc_stats(R, HIT, cycle=5, stream_id=1)  # lost
        c.inc_stats(R, HIT, cycle=6, stream_id=1)  # lands
        assert c.get(R, HIT) == 2
        assert c.lost_updates == 1

    def test_different_cells_do_not_collide(self):
        c = CleanStatTable()
        c.inc_stats(R, HIT, cycle=5, stream_id=0)
        c.inc_stats(R, MISS, cycle=5, stream_id=1)
        assert c.get(R, HIT) == 1 and c.get(R, MISS) == 1

    @settings(max_examples=50, deadline=None)
    @given(
        st.lists(
            st.tuples(st.integers(0, 3), st.integers(0, 2), st.integers(0, 200)),
            max_size=80,
        )
    )
    def test_property_clean_never_exceeds_tip(self, events):
        """The paper's §5.2 invariant: Σ tip ≥ clean, always."""
        tip, clean = StatTable(), CleanStatTable()
        for stream, outcome, cycle in events:
            tip.inc_stats(R, outcome, stream)
            clean.inc_stats(R, outcome, cycle=cycle, stream_id=stream)
        agg = tip.aggregate()
        for o in range(3):
            assert int(agg[R, o]) >= clean.get(R, o)
        assert int(agg.sum()) == clean.matrix().sum() + clean.lost_updates
