"""Serving-engine regressions: run_until_idle return value + token sampling."""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.faults import FaultPlan
from repro.models import init_params, model_defs
from repro.serve import Engine, Request, ServeConfig

KEY = jax.random.PRNGKey(7)


@pytest.fixture(scope="module")
def model_setup():
    cfg = get_smoke_config("deepseek-7b")
    params = init_params(model_defs(cfg), KEY, cfg.param_jdtype())
    return cfg, params


def _requests(cfg, n, seed=0, max_new=4):
    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt=rng.integers(0, cfg.vocab_size, (5 + i,)).astype(np.int32),
            max_new_tokens=max_new,
            name=f"r{i}",
        )
        for i in range(n)
    ]


class TestRunUntilIdle:
    def test_returns_retired_requests(self, model_setup):
        """Regression: run_until_idle used to return a never-appended []."""
        cfg, params = model_setup
        eng = Engine(cfg, params, ServeConfig(n_slots=2, max_len=64))
        reqs = _requests(cfg, 3)
        for r in reqs:
            eng.submit(r)
        done = eng.run_until_idle()
        assert sorted(r.name for r in done) == ["r0", "r1", "r2"]
        assert all(r.done for r in done)
        assert all(len(r.generated) == r.max_new_tokens for r in done)

    def test_returns_only_this_calls_retirements(self, model_setup):
        cfg, params = model_setup
        eng = Engine(cfg, params, ServeConfig(n_slots=2, max_len=64))
        first = _requests(cfg, 1, seed=1)[0]
        eng.submit(first)
        done1 = eng.run_until_idle()
        assert [r.name for r in done1] == [first.name]
        second = _requests(cfg, 2, seed=2)
        for r in second:
            eng.submit(r)
        done2 = eng.run_until_idle()
        assert sorted(r.name for r in done2) == sorted(r.name for r in second)
        assert all(r is not first for r in done2)

    def test_idle_engine_returns_empty(self, model_setup):
        cfg, params = model_setup
        eng = Engine(cfg, params, ServeConfig(n_slots=2, max_len=64))
        assert eng.run_until_idle() == []

    def test_retired_buffer_is_drained_not_pinned(self, model_setup):
        """The engine must not retain retired requests forever."""
        cfg, params = model_setup
        eng = Engine(cfg, params, ServeConfig(n_slots=1, max_len=64))
        req = _requests(cfg, 1, seed=6, max_new=3)[0]
        eng.submit(req)
        while not req.done:
            eng.step()  # manual stepping → collected via drain_retired
        drained = eng.drain_retired()
        assert [r.name for r in drained] == [req.name]
        assert eng.drain_retired() == []
        eng.submit(_requests(cfg, 1, seed=7, max_new=3)[0])
        assert len(eng.run_until_idle()) == 1
        assert eng._retired == []  # run_until_idle consumed what it returned


class TestSampling:
    def test_sampled_tokens_valid_and_seed_deterministic(self, model_setup):
        cfg, params = model_setup
        outs = []
        for _ in range(2):
            eng = Engine(
                cfg,
                params,
                ServeConfig(n_slots=1, max_len=64, greedy=False, temperature=1.0, sample_seed=3),
            )
            req = _requests(cfg, 1, seed=4, max_new=6)[0]
            eng.submit(req)
            eng.run_until_idle()
            assert all(0 <= t < cfg.vocab_size for t in req.generated)
            outs.append(list(req.generated))
        assert outs[0] == outs[1]  # same seed → same sampled stream

    def test_greedy_unchanged_by_sampling_knobs(self, model_setup):
        """greedy=True must ignore temperature/seed (pure argmax path)."""
        cfg, params = model_setup
        gens = []
        for seed in (0, 99):
            eng = Engine(
                cfg, params, ServeConfig(n_slots=1, max_len=64, greedy=True, sample_seed=seed)
            )
            req = _requests(cfg, 1, seed=5, max_new=5)[0]
            eng.submit(req)
            eng.run_until_idle()
            gens.append(list(req.generated))
        assert gens[0] == gens[1]

    def test_select_tokens_shared_helper_shapes(self, model_setup):
        cfg, params = model_setup
        eng = Engine(cfg, params, ServeConfig(n_slots=2, max_len=64, greedy=False, sample_seed=1))
        logits = jax.numpy.asarray(np.random.default_rng(0).normal(size=(2, cfg.vocab_size)))
        toks = eng._select_tokens(logits)
        assert toks.shape == (2,)
        assert toks.dtype == np.int32
        assert all(0 <= int(t) < cfg.vocab_size for t in toks)


class TestFaultInjection:
    """Request-layer faults (docs/DESIGN.md §5.11): overflow shedding,
    retry/backoff, deadlines, cancellation — each accounted exactly once in
    the per-stream fault lanes."""

    def test_overflow_sheds_retries_and_conserves(self, model_setup):
        cfg, params = model_setup
        plan = FaultPlan(seed=3, queue_limit=2, max_retries=2, backoff_base=1)
        eng = Engine(cfg, params, ServeConfig(n_slots=1, max_len=64, fault_plan=plan))
        for r in _requests(cfg, 5, seed=8):
            eng.submit(r)
        done = eng.run_until_idle()
        lanes = eng.fault_summary()["lanes"]
        terminal_shed = sum(1 for r in done if r.status == "shed")
        recovered = sum(1 for r in done if r.status == "done" and r.retries > 0)
        # conservation: every shed event either became a retry or went terminal
        assert lanes["SHED"] == terminal_shed + lanes["RETRY"]
        assert lanes["RECOVERED"] == recovered > 0
        assert lanes["TIMEOUT_EXPIRED"] == 0
        assert terminal_shed > 0  # budget is finite: someone was dropped
        shed = [r for r in done if r.status == "shed"]
        assert all(r.retries == plan.max_retries for r in shed)

    def test_priority_decides_shed_victim(self, model_setup):
        cfg, params = model_setup
        plan = FaultPlan(queue_limit=1, max_retries=0)
        eng = Engine(cfg, params, ServeConfig(n_slots=1, max_len=64, fault_plan=plan))
        lo, hi = _requests(cfg, 2, seed=9)
        lo.priority, hi.priority = 0, 5
        eng.submit(lo)
        eng.submit(hi)  # overflow: lowest priority is shed, not the arrival
        assert lo.status == "shed" and lo.done
        done = eng.run_until_idle() + eng.drain_retired()
        assert {r.name: r.status for r in done}[hi.name] == "done"

    def test_deadline_expiry_across_queue_and_slots(self, model_setup):
        cfg, params = model_setup
        plan = FaultPlan(deadline_steps=3)
        eng = Engine(cfg, params, ServeConfig(n_slots=1, max_len=64, fault_plan=plan))
        reqs = _requests(cfg, 3, seed=10, max_new=8)
        for r in reqs:
            eng.submit(r)
        done = eng.run_until_idle()
        lanes = eng.fault_summary()["lanes"]
        timeouts = [r for r in done if r.status == "timeout"]
        assert timeouts and lanes["TIMEOUT_EXPIRED"] == len(timeouts)
        assert all(r.done for r in done)

    def test_per_request_deadline_without_plan(self, model_setup):
        cfg, params = model_setup
        eng = Engine(cfg, params, ServeConfig(n_slots=1, max_len=64))
        fast, slow = _requests(cfg, 2, seed=11, max_new=8)
        slow.deadline_steps = 2
        eng.submit(fast)
        eng.submit(slow)
        statuses = {r.name: r.status for r in eng.run_until_idle()}
        assert statuses[fast.name] == "done"
        assert statuses[slow.name] == "timeout"

    def test_cancel_everywhere(self, model_setup):
        cfg, params = model_setup
        eng = Engine(cfg, params, ServeConfig(n_slots=1, max_len=64))
        queued, active = _requests(cfg, 2, seed=12, max_new=6)
        eng.submit(active)
        eng.step()  # active now holds the slot
        eng.submit(queued)
        assert eng.cancel(queued) is True
        assert eng.cancel(active) is True
        assert eng.cancel(active) is False  # already gone
        assert queued.status == active.status == "cancelled"
        assert eng.run_until_idle() == []
        assert eng.fault_summary()["lanes"]["SHED"] == 2

    def test_recovered_requests_complete_normally(self, model_setup):
        """A shed-then-retried request still generates its full output."""
        cfg, params = model_setup
        plan = FaultPlan(queue_limit=1, max_retries=3, backoff_base=1)
        eng = Engine(cfg, params, ServeConfig(n_slots=1, max_len=64, fault_plan=plan))
        reqs = _requests(cfg, 3, seed=13, max_new=3)
        for r in reqs:
            eng.submit(r)
        done = eng.run_until_idle()
        finished = [r for r in done if r.status == "done"]
        assert all(len(r.generated) == r.max_new_tokens for r in finished)
        assert any(r.retries > 0 for r in finished)


class TestLivelockGuard:
    def test_eos_free_request_raises_instead_of_spinning(self, model_setup):
        """Regression: an EOS-free request with max_new_tokens beyond the
        step budget used to silently truncate; now the guard names it."""
        cfg, params = model_setup
        eng = Engine(cfg, params, ServeConfig(n_slots=1, max_len=64))
        eng.submit(Request(prompt=np.arange(5, dtype=np.int32),
                           max_new_tokens=10**6, name="runaway"))
        with pytest.raises(RuntimeError, match="runaway"):
            eng.run_until_idle(max_steps=5)

    def test_wall_clock_budget(self, model_setup):
        cfg, params = model_setup
        eng = Engine(cfg, params, ServeConfig(n_slots=1, max_len=64))
        eng.submit(Request(prompt=np.arange(5, dtype=np.int32),
                           max_new_tokens=10**6, name="slowpoke"))
        with pytest.raises(RuntimeError, match="slowpoke"):
            eng.run_until_idle(deadline_s=0.0)
